//! Runtime integration: the artifact contract (shapes, determinism, clip
//! bounds, contribution-map mass) checked end-to-end.
//!
//! The contract checks are parameterized over a runtime + model names and
//! run **unconditionally** against the built-in reference manifest (pCTR
//! and the native NLU transformer).  The same checks run a second time over
//! real AOT artifacts when `artifacts/manifest.txt` exists and the `xla`
//! feature is compiled in — that leg alone is gated, because it is the only
//! part that needs the PJRT backend.

use sparse_dp_emb::models::ParamStore;
use sparse_dp_emb::runtime::{HostTensor, Runtime};
use sparse_dp_emb::util::rng::Xoshiro256;

/// Artifact-gated runtime for the xla-specific leg only.
fn artifact_runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping xla leg: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    if !cfg!(feature = "xla") {
        eprintln!("skipping xla leg: artifacts present but built without --features xla");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime init"))
}

fn pctr_batch_tensors(
    rt: &Runtime,
    model_name: &str,
    seed: u64,
) -> (Vec<HostTensor>, Vec<i32>, usize, usize) {
    let model = rt.manifest.model(model_name).unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    let b = model.attr_usize("batch_size").unwrap();
    let nn = model.attr_usize("num_numeric").unwrap();
    let nf = vocabs.len();
    let mut rng = Xoshiro256::seed_from(seed);
    let cat: Vec<i32> = (0..b * nf)
        .map(|i| (rng.below(vocabs[i % nf] as u64)) as i32)
        .collect();
    let num: Vec<f32> = (0..b * nn).map(|_| rng.gauss() as f32).collect();
    let y: Vec<f32> = (0..b).map(|_| (rng.below(2)) as f32).collect();
    (
        vec![
            HostTensor::i32(vec![b, nf], cat.clone()),
            HostTensor::f32(vec![b, nn], num),
            HostTensor::f32(vec![b], y),
        ],
        cat,
        b,
        nf,
    )
}

fn check_pctr_fwd(rt: &Runtime, model_name: &str, artifact: &str) {
    let model = rt.manifest.model(model_name).unwrap();
    let store = ParamStore::init(model, 3).unwrap();
    let (batch, _, b, _) = pctr_batch_tensors(rt, model_name, 17);

    let mut inputs = store.tensors();
    inputs.extend(batch);
    let out1 = rt.execute(artifact, &inputs).unwrap();
    assert_eq!(out1.len(), 2);
    let loss = out1[0].scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_eq!(out1[1].dims(), &[b]);

    // executing twice with identical inputs is bit-identical (no hidden RNG
    // inside the artifact — all randomness is ours)
    let out2 = rt.execute(artifact, &inputs).unwrap();
    assert_eq!(out1[0], out2[0]);
    assert_eq!(out1[1], out2[1]);
}

fn check_pctr_grads(rt: &Runtime, model_name: &str, artifact: &str) {
    let model = rt.manifest.model(model_name).unwrap();
    let store = ParamStore::init(model, 3).unwrap();
    let art = rt.manifest.artifact(artifact).unwrap();
    store.check_against(&art.inputs).unwrap();

    let (batch, cat, b, nf) = pctr_batch_tensors(rt, model_name, 11);
    let mut inputs = store.tensors();
    inputs.extend(batch);
    inputs.push(HostTensor::f32(vec![1], vec![1.0])); // c1
    inputs.push(HostTensor::f32(vec![1], vec![0.5])); // c2
    let outs = rt.execute_named(artifact, &inputs).unwrap();

    // (1) loss is finite
    let loss = outs["loss"].scalar().unwrap();
    assert!(loss.is_finite());

    // (2) clip scales are in (0, 1]
    let scales = outs["scales"].as_f32().unwrap();
    assert_eq!(scales.len(), b);
    assert!(scales.iter().all(|&s| s > 0.0 && s <= 1.0 + 1e-6));

    // (3) contribution counts: nonzeros exactly at activated offset rows,
    //     total mass = B * min(1, c1/sqrt(F))
    let counts = outs["counts"].as_f32().unwrap();
    let offsets = model.attr_usize_list("row_offsets").unwrap();
    let mut activated = std::collections::HashSet::new();
    for i in 0..b {
        for f in 0..nf {
            activated.insert(offsets[f] + cat[i * nf + f] as usize);
        }
    }
    let nz: std::collections::HashSet<usize> = counts
        .iter()
        .enumerate()
        .filter(|(_, &v)| v != 0.0)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(nz, activated);
    let w = (1.0f64 / (nf as f64).sqrt()).min(1.0);
    let total: f64 = counts.iter().map(|&v| v as f64).sum();
    assert!(
        (total - w * (b * nf) as f64).abs() < 1e-2,
        "count mass {total} vs {}",
        w * (b * nf) as f64
    );

    // (4) per-example clipped grad norm <= c2: the scaled zgrads alone must
    //     satisfy ||zg_i|| <= c2
    let zg = outs["zgrads_scaled"].as_f32().unwrap();
    let d_total = zg.len() / b;
    for i in 0..b {
        let sq: f64 = zg[i * d_total..(i + 1) * d_total]
            .iter()
            .map(|&v| (v as f64).powi(2))
            .sum();
        assert!(sq.sqrt() <= 0.5 * (1.0 + 1e-4), "example {i}: {}", sq.sqrt());
    }
}

fn check_nlu_grads(rt: &Runtime, model_name: &str, artifact: &str, probe_token: i32) {
    let model = rt.manifest.model(model_name).unwrap();
    let store = ParamStore::init(model, 5).unwrap();
    let vocab = model.attr_usize("vocab").unwrap();
    let b = model.attr_usize("batch_size").unwrap();
    let t = model.attr_usize("seq_len").unwrap();
    assert!((probe_token as usize) < vocab);
    let mut rng = Xoshiro256::seed_from(23);
    let mut ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    // force repeated tokens in example 0 to exercise the within-example sum
    for slot in ids.iter_mut().take(t) {
        *slot = probe_token;
    }
    let labels: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();

    let mut inputs = store.tensors();
    inputs.push(HostTensor::i32(vec![b, t], ids.clone()));
    inputs.push(HostTensor::i32(vec![b], labels));
    inputs.push(HostTensor::f32(vec![1], vec![100.0])); // c1 loose
    inputs.push(HostTensor::f32(vec![1], vec![0.05])); // c2 tight
    let outs = rt.execute_named(artifact, &inputs).unwrap();

    // scattered row norm for the all-repeated example obeys the clip
    let zg = outs["zgrads_scaled"].as_f32().unwrap();
    let d = zg.len() / (b * t);
    let mut row = vec![0f64; d];
    for p in 0..t {
        for k in 0..d {
            row[k] += zg[(p * d) + k] as f64;
        }
    }
    let norm: f64 = row.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(norm <= 0.05 * (1.0 + 1e-3), "scattered norm {norm} > c2");

    // counts: the probe token gets exactly 1 contribution from example 0
    // (unique within the example), plus whatever other examples add
    let counts = outs["counts"].as_f32().unwrap();
    assert!(counts[probe_token as usize] >= 1.0 - 1e-4);

    // determinism of the full grads tuple
    let again = rt.execute_named(artifact, &inputs).unwrap();
    assert_eq!(outs["zgrads_scaled"], again["zgrads_scaled"]);
    assert_eq!(outs["counts"], again["counts"]);
}

// ---- reference runtime: unconditional, artifact-free ----

#[test]
fn reference_pctr_fwd_contract() {
    check_pctr_fwd(&Runtime::builtin(), "criteo-small", "pctr_fwd");
}

#[test]
fn reference_pctr_grads_contract() {
    check_pctr_grads(&Runtime::builtin(), "criteo-small", "pctr_grads");
}

#[test]
fn reference_nlu_grads_contract() {
    check_nlu_grads(&Runtime::builtin(), "nlu-tiny", "nlu_tiny_grads", 77);
}

#[test]
fn reference_nlu_fwd_shapes_and_determinism() {
    let rt = Runtime::builtin();
    let model = rt.manifest.model("nlu-tiny").unwrap();
    let store = ParamStore::init(model, 9).unwrap();
    let (vocab, b, t) = (
        model.attr_usize("vocab").unwrap(),
        model.attr_usize("batch_size").unwrap(),
        model.attr_usize("seq_len").unwrap(),
    );
    let c = model.attr_usize("num_classes").unwrap();
    let mut rng = Xoshiro256::seed_from(31);
    let ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    let labels: Vec<i32> = (0..b).map(|_| rng.below(c as u64) as i32).collect();
    let mut inputs = store.tensors();
    inputs.push(HostTensor::i32(vec![b, t], ids));
    inputs.push(HostTensor::i32(vec![b], labels));
    let out1 = rt.execute("nlu_tiny_fwd", &inputs).unwrap();
    assert_eq!(out1.len(), 2);
    assert!(out1[0].scalar().unwrap().is_finite());
    assert_eq!(out1[1].dims(), &[b, c]);
    let out2 = rt.execute("nlu_tiny_fwd", &inputs).unwrap();
    assert_eq!(out1, out2);
}

#[test]
fn reference_nlu_sparse_rows_align_with_dense_scatter() {
    // The row-sparse table gradient assembled from zgrads_scaled must equal
    // a brute-force dense scatter-add over (example, position) token ids.
    use sparse_dp_emb::coordinator::step::{assemble_text, output_plan, EmbTable, OutputKind};
    use sparse_dp_emb::data::TextBatch;

    let rt = Runtime::builtin();
    let model = rt.manifest.model("nlu-tiny").unwrap();
    let store = ParamStore::init(model, 5).unwrap();
    let (vocab, b, t) = (
        model.attr_usize("vocab").unwrap(),
        model.attr_usize("batch_size").unwrap(),
        model.attr_usize("seq_len").unwrap(),
    );
    let d = model.attr_usize("d_model").unwrap();
    let mut rng = Xoshiro256::seed_from(41);
    let ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    let labels: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();
    let mut inputs = store.tensors();
    inputs.push(HostTensor::i32(vec![b, t], ids.clone()));
    inputs.push(HostTensor::i32(vec![b], labels.clone()));
    inputs.push(HostTensor::f32(vec![1], vec![1.0]));
    inputs.push(HostTensor::f32(vec![1], vec![0.5]));
    let outs = rt.execute("nlu_tiny_grads", &inputs).unwrap();

    let art = rt.manifest.artifact("nlu_tiny_grads").unwrap();
    let plan: Vec<OutputKind> = output_plan(art, &store).unwrap();
    let tables = vec![EmbTable {
        param_index: 0,
        name: "emb_table".to_string(),
        vocab,
        dim: d,
        row_offset: 0,
        grad_offset: 0,
    }];
    let batch = TextBatch { batch_size: b, seq_len: t, ids: ids.clone(), labels };
    let bundle = assemble_text(&plan, &outs, &tables, &batch, t, true).unwrap();
    assert_eq!(bundle.table_grads.len(), 1);
    let sparse_dense = bundle.table_grads[0].to_dense();

    // brute-force dense reference
    let zg_idx = art.output_index("zgrads_scaled").unwrap();
    let zg = outs[zg_idx].as_f32().unwrap();
    let mut want = vec![0f32; vocab * d];
    for (slot, &id) in ids.iter().enumerate() {
        let row = id as usize;
        for k in 0..d {
            want[row * d + k] += zg[slot * d + k];
        }
    }
    assert_eq!(sparse_dense, want, "sparse rows must equal the dense scatter");
}

#[test]
fn reference_rejects_bad_shapes() {
    let rt = Runtime::builtin();
    let model = rt.manifest.model("criteo-small").unwrap();
    let store = ParamStore::init(model, 3).unwrap();
    let mut inputs = store.tensors();
    // wrong batch rank for cat_idx
    inputs.push(HostTensor::i32(vec![4], vec![0, 0, 0, 0]));
    let err = rt.execute("pctr_fwd", &inputs).unwrap_err().to_string();
    assert!(err.contains("inputs"), "unexpected error: {err}");
}

// ---- xla leg: same contracts over real AOT artifacts (gated) ----

#[test]
fn xla_artifact_contracts() {
    let Some(rt) = artifact_runtime() else { return };
    check_pctr_fwd(&rt, "criteo-small", "pctr_fwd");
    check_pctr_grads(&rt, "criteo-small", "pctr_grads");
    check_nlu_grads(&rt, "nlu-roberta", "nlu_grads", 777);
}
