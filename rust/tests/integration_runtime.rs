//! Integration tests over real AOT artifacts: load, execute, shape-check,
//! and verify the numerical contract between the artifacts and the Rust
//! coordinator.  Skipped gracefully if `make artifacts` has not run.

use sparse_dp_emb::models::ParamStore;
use sparse_dp_emb::runtime::{HostTensor, Runtime};
use sparse_dp_emb::util::rng::Xoshiro256;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    if !cfg!(feature = "xla") {
        // These tests verify the PJRT/HLO artifact contract; the reference
        // backend would execute (or, for NLU, reject) them natively.
        eprintln!("skipping: artifacts present but built without --features xla");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime init"))
}

fn pctr_batch_tensors(
    rt: &Runtime,
    seed: u64,
) -> (Vec<HostTensor>, Vec<i32>, usize, usize) {
    let model = rt.manifest.model("criteo-small").unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    let b = model.attr_usize("batch_size").unwrap();
    let nn = model.attr_usize("num_numeric").unwrap();
    let nf = vocabs.len();
    let mut rng = Xoshiro256::seed_from(seed);
    let cat: Vec<i32> = (0..b * nf)
        .map(|i| (rng.below(vocabs[i % nf] as u64)) as i32)
        .collect();
    let num: Vec<f32> = (0..b * nn).map(|_| rng.gauss() as f32).collect();
    let y: Vec<f32> = (0..b).map(|_| (rng.below(2)) as f32).collect();
    (
        vec![
            HostTensor::i32(vec![b, nf], cat.clone()),
            HostTensor::f32(vec![b, nn], num),
            HostTensor::f32(vec![b], y),
        ],
        cat,
        b,
        nf,
    )
}

#[test]
fn pctr_fwd_shapes_and_determinism() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("criteo-small").unwrap();
    let store = ParamStore::init(model, 3).unwrap();
    let (batch, _, b, _) = pctr_batch_tensors(&rt, 17);

    let mut inputs = store.tensors();
    inputs.extend(batch.clone());
    let out1 = rt.execute("pctr_fwd", &inputs).unwrap();
    assert_eq!(out1.len(), 2);
    let loss = out1[0].scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_eq!(out1[1].dims(), &[b]);

    // executing twice with identical inputs is bit-identical (no hidden RNG
    // inside the artifact — all randomness is ours)
    let out2 = rt.execute("pctr_fwd", &inputs).unwrap();
    assert_eq!(out1[0], out2[0]);
    assert_eq!(out1[1], out2[1]);
}

#[test]
fn pctr_grads_contract() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("criteo-small").unwrap();
    let store = ParamStore::init(model, 3).unwrap();
    let art = rt.manifest.artifact("pctr_grads").unwrap();
    store.check_against(&art.inputs).unwrap();

    let (batch, cat, b, nf) = pctr_batch_tensors(&rt, 11);
    let mut inputs = store.tensors();
    inputs.extend(batch);
    inputs.push(HostTensor::f32(vec![1], vec![1.0])); // c1
    inputs.push(HostTensor::f32(vec![1], vec![0.5])); // c2
    let outs = rt.execute_named("pctr_grads", &inputs).unwrap();

    // (1) loss agrees with the fwd artifact at huge clip... here: finite
    let loss = outs["loss"].scalar().unwrap();
    assert!(loss.is_finite());

    // (2) clip scales are in (0, 1]
    let scales = outs["scales"].as_f32().unwrap();
    assert_eq!(scales.len(), b);
    assert!(scales.iter().all(|&s| s > 0.0 && s <= 1.0 + 1e-6));

    // (3) contribution counts: nonzeros exactly at activated offset rows,
    //     total mass = B * min(1, c1/sqrt(F))
    let counts = outs["counts"].as_f32().unwrap();
    let offsets = model.attr_usize_list("row_offsets").unwrap();
    let mut activated = std::collections::HashSet::new();
    for i in 0..b {
        for f in 0..nf {
            activated.insert(offsets[f] + cat[i * nf + f] as usize);
        }
    }
    let nz: std::collections::HashSet<usize> = counts
        .iter()
        .enumerate()
        .filter(|(_, &v)| v != 0.0)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(nz, activated);
    let w = (1.0f64 / (nf as f64).sqrt()).min(1.0);
    let total: f64 = counts.iter().map(|&v| v as f64).sum();
    assert!(
        (total - w * (b * nf) as f64).abs() < 1e-2,
        "count mass {total} vs {}",
        w * (b * nf) as f64
    );

    // (4) per-example clipped grad norm <= c2: check via zgrads + dense
    //     grads... the scaled zgrads alone must satisfy ||zg_i|| <= c2
    let zg = outs["zgrads_scaled"].as_f32().unwrap();
    let d_total = zg.len() / b;
    for i in 0..b {
        let sq: f64 = zg[i * d_total..(i + 1) * d_total]
            .iter()
            .map(|&v| (v as f64).powi(2))
            .sum();
        assert!(sq.sqrt() <= 0.5 * (1.0 + 1e-4), "example {i}: {}", sq.sqrt());
    }
}

#[test]
fn nlu_grads_contract() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("nlu-roberta").unwrap();
    let store = ParamStore::init(model, 5).unwrap();
    let vocab = model.attr_usize("vocab").unwrap();
    let b = model.attr_usize("batch_size").unwrap();
    let t = model.attr_usize("seq_len").unwrap();
    let mut rng = Xoshiro256::seed_from(23);
    let mut ids: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    // force repeated tokens in example 0 to exercise the within-example sum
    for p in 0..t {
        ids[p] = 777;
    }
    let labels: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();

    let mut inputs = store.tensors();
    inputs.push(HostTensor::i32(vec![b, t], ids.clone()));
    inputs.push(HostTensor::i32(vec![b], labels));
    inputs.push(HostTensor::f32(vec![1], vec![100.0])); // c1 loose
    inputs.push(HostTensor::f32(vec![1], vec![0.05])); // c2 tight
    let outs = rt.execute_named("nlu_grads", &inputs).unwrap();

    // scattered row norm for the all-repeated example obeys the clip
    let zg = outs["zgrads_scaled"].as_f32().unwrap();
    let d = zg.len() / (b * t);
    let mut row = vec![0f64; d];
    for p in 0..t {
        for k in 0..d {
            row[k] += zg[(p * d) + k] as f64;
        }
    }
    let norm: f64 = row.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(norm <= 0.05 * (1.0 + 1e-3), "scattered norm {norm} > c2");

    // counts: token 777 gets exactly 1 contribution from example 0 (unique
    // within the example), plus whatever other examples add
    let counts = outs["counts"].as_f32().unwrap();
    assert!(counts[777] >= 1.0 - 1e-4);
}

#[test]
fn artifact_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("criteo-small").unwrap();
    let store = ParamStore::init(model, 3).unwrap();
    let mut inputs = store.tensors();
    // wrong batch rank for cat_idx
    inputs.push(HostTensor::i32(vec![4], vec![0, 0, 0, 0]));
    let err = rt.execute("pctr_fwd", &inputs).unwrap_err().to_string();
    assert!(err.contains("inputs"), "unexpected error: {err}");
}
