//! End-to-end coordinator integration: every algorithm trains for a handful
//! of steps on real artifacts; invariants across algorithms are checked
//! (loss decreases non-privately, gradient-size ordering, survivor
//! semantics, frozen embeddings untouched).

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{Algorithm, StreamingTrainer, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo, SynthText, TextConfig};
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::util::rng::Xoshiro256;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    if !cfg!(feature = "xla") {
        // NLU models here require the PJRT backend; without it the
        // reference runtime would reject them mid-test instead of skipping.
        // (The pctr coverage runs artifact-free in tests/engine.rs.)
        eprintln!("skipping: artifacts present but built without --features xla");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime init"))
}

fn base_cfg(algo: Algorithm) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-small".into();
    cfg.algorithm = algo;
    cfg.steps = 12;
    cfg.eval_batches = 4;
    cfg.c2 = 0.5;
    cfg
}

fn criteo_gen(rt: &Runtime, cfg: &RunConfig) -> SynthCriteo {
    let model = rt.manifest.model(&cfg.model).unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    SynthCriteo::new(CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A))
}

#[test]
fn nonprivate_loss_decreases() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_cfg(Algorithm::NonPrivate);
    cfg.steps = 60;
    let gen = criteo_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let out = trainer.run_pctr(&gen).unwrap();
    let first: f64 = out.loss_history[..10].iter().sum::<f64>() / 10.0;
    let last: f64 = out.loss_history[out.loss_history.len() - 10..]
        .iter()
        .sum::<f64>()
        / 10.0;
    assert!(
        last < first - 0.01,
        "loss did not decrease: {first:.4} -> {last:.4}"
    );
    assert!(out.utility > 0.55, "AUC {africa}", africa = out.utility);
}

#[test]
fn all_algorithms_run_and_grad_size_ordering_holds() {
    let Some(rt) = runtime() else { return };
    let mut sizes = std::collections::HashMap::new();
    for algo in [
        Algorithm::DpSgd,
        Algorithm::DpAdaFest,
        Algorithm::DpAdaFestPlus,
        Algorithm::DpFest,
        Algorithm::ExpSelection,
    ] {
        let mut cfg = base_cfg(algo);
        cfg.tau = 5.0;
        cfg.fest_top_k = 1024;
        cfg.exp_select_m = 512;
        let gen = criteo_gen(&rt, &cfg);
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let out = trainer.run_pctr(&gen).unwrap();
        assert!(out.loss_history.iter().all(|l| l.is_finite()), "{algo:?}");
        assert!(out.utility.is_finite());
        sizes.insert(algo, out.emb_grad_coords_per_step);
    }
    let dense = sizes[&Algorithm::DpSgd];
    // every sparsity-preserving variant noises strictly fewer coordinates
    for algo in [
        Algorithm::DpAdaFest,
        Algorithm::DpAdaFestPlus,
        Algorithm::DpFest,
        Algorithm::ExpSelection,
    ] {
        assert!(
            sizes[&algo] < dense * 0.8,
            "{algo:?} size {} not < dense {dense}",
            sizes[&algo]
        );
    }
    // AdaFEST+ intersects with the FEST set, so it cannot exceed AdaFEST
    assert!(
        sizes[&Algorithm::DpAdaFestPlus] <= sizes[&Algorithm::DpAdaFest] * 1.05,
        "+: {} vs {}",
        sizes[&Algorithm::DpAdaFestPlus],
        sizes[&Algorithm::DpAdaFest]
    );
}

#[test]
fn dp_sgd_noises_every_embedding_coordinate() {
    let Some(rt) = runtime() else { return };
    let cfg = base_cfg(Algorithm::DpSgd);
    let gen = criteo_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let emb_total = trainer.store.embedding_coords();
    let mut rng = Xoshiro256::seed_from(1);
    let batch = gen.batch(0, trainer.batch_size(), &mut rng);
    let stats = trainer.step_pctr(&batch).unwrap();
    assert_eq!(stats.emb_coords_noised, emb_total);
    assert_eq!(stats.dense_coords_noised, trainer.store.dense_coords());
}

#[test]
fn tau_monotonically_shrinks_gradient_size() {
    let Some(rt) = runtime() else { return };
    let mut prev = f64::INFINITY;
    for tau in [0.5, 5.0, 50.0] {
        let mut cfg = base_cfg(Algorithm::DpAdaFest);
        cfg.tau = tau;
        let gen = criteo_gen(&rt, &cfg);
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let out = trainer.run_pctr(&gen).unwrap();
        assert!(
            out.emb_grad_coords_per_step <= prev * 1.1,
            "tau={tau}: {} > prev {prev}",
            out.emb_grad_coords_per_step
        );
        prev = out.emb_grad_coords_per_step;
    }
}

#[test]
fn frozen_embedding_is_untouched() {
    let Some(rt) = runtime() else { return };
    let mut cfg = RunConfig::default();
    cfg.model = "nlu-roberta".into();
    cfg.algorithm = Algorithm::DpSgd;
    cfg.freeze_embedding = true;
    cfg.steps = 3;
    cfg.eval_batches = 2;
    let model = rt.manifest.model(&cfg.model).unwrap();
    let gen = SynthText::new(TextConfig::new(
        model.attr_usize("vocab").unwrap(),
        model.attr_usize("seq_len").unwrap(),
        model.attr_usize("num_classes").unwrap(),
        3,
    ));
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let emb_before = trainer
        .store
        .get("emb_table")
        .unwrap()
        .tensor
        .as_f32()
        .unwrap()
        .to_vec();
    let mut rng = Xoshiro256::seed_from(2);
    for _ in 0..3 {
        let b = gen.batch(trainer.batch_size(), &mut rng);
        let stats = trainer.step_text(&b).unwrap();
        assert_eq!(stats.emb_coords_noised, 0);
    }
    let emb_after = trainer
        .store
        .get("emb_table")
        .unwrap()
        .tensor
        .as_f32()
        .unwrap();
    assert_eq!(emb_before.as_slice(), emb_after);
}

#[test]
fn nlu_and_xlmr_train() {
    let Some(rt) = runtime() else { return };
    for model_name in ["nlu-roberta", "nlu-xlmr"] {
        let mut cfg = RunConfig::default();
        cfg.model = model_name.into();
        cfg.algorithm = Algorithm::DpAdaFest;
        cfg.steps = 4;
        cfg.eval_batches = 2;
        cfg.tau = 2.0;
        let model = rt.manifest.model(&cfg.model).unwrap();
        let gen = SynthText::new(TextConfig::new(
            model.attr_usize("vocab").unwrap(),
            model.attr_usize("seq_len").unwrap(),
            model.attr_usize("num_classes").unwrap(),
            7,
        ));
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let out = trainer.run_text(&gen).unwrap();
        assert!(out.utility.is_finite() && out.utility >= 0.0);
        assert!(out.reduction_factor > 1.0, "{model_name}: no reduction");
    }
}

#[test]
fn streaming_protocol_runs_and_evals_future_days() {
    let Some(rt) = runtime() else { return };
    let mut cfg = base_cfg(Algorithm::DpAdaFestPlus);
    cfg.steps = 36; // 2/day
    cfg.streaming_period = 2;
    cfg.fest_top_k = 2048;
    let model = rt.manifest.model(&cfg.model).unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    let gen = SynthCriteo::new(CriteoConfig::new(vocabs, 9).with_drift());
    let trainer = Trainer::new(cfg, &rt).unwrap();
    let mut st = StreamingTrainer::new(trainer, 2);
    let out = st.run(&gen).unwrap();
    assert_eq!(out.per_day_auc.len(), 6);
    assert!(out.reselections >= 1);
    assert!(out.outcome.utility.is_finite());
}

#[test]
fn loraemb_model_trains_densely() {
    let Some(rt) = runtime() else { return };
    let mut cfg = RunConfig::default();
    cfg.model = "nlu-roberta-loraemb16".into();
    cfg.algorithm = Algorithm::DpSgd;
    cfg.steps = 3;
    cfg.eval_batches = 2;
    let model = rt.manifest.model(&cfg.model).unwrap();
    let gen = SynthText::new(TextConfig::new(
        model.attr_usize("vocab").unwrap(),
        model.attr_usize("seq_len").unwrap(),
        model.attr_usize("num_classes").unwrap(),
        7,
    ));
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let emb_lora_coords = trainer.store.get("emb_lora_a").unwrap().num_elements();
    let out = trainer.run_text(&gen).unwrap();
    // dense noise on the LoRA-A factor every step
    assert!((out.emb_grad_coords_per_step - emb_lora_coords as f64).abs() < 1.0);
}
