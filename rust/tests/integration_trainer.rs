//! End-to-end coordinator integration: every algorithm trains for a handful
//! of steps; invariants across algorithms are checked (loss decreases
//! non-privately, gradient-size ordering, survivor semantics, frozen
//! embeddings untouched).
//!
//! Everything here runs **unconditionally** over the built-in reference
//! manifest — pCTR on `criteo-small`/`criteo-tiny`, NLU on the native
//! transformer `nlu-tiny`.  Only the final section (artifact-only models:
//! the RoBERTa/XLM-R stand-ins and the LoRA-on-embedding variants) keeps
//! the `artifacts/manifest.txt` + `--features xla` gate.

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{Algorithm, StreamingTrainer, Trainer};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo, SynthText, TextConfig};
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::util::rng::Xoshiro256;

fn base_cfg(algo: Algorithm) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-small".into();
    cfg.algorithm = algo;
    cfg.steps = 12;
    cfg.eval_batches = 4;
    cfg.c2 = 0.5;
    cfg
}

fn criteo_gen(rt: &Runtime, cfg: &RunConfig) -> SynthCriteo {
    let model = rt.manifest.model(&cfg.model).unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    SynthCriteo::new(CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A))
}

fn text_gen(rt: &Runtime, cfg: &RunConfig) -> SynthText {
    let model = rt.manifest.model(&cfg.model).unwrap();
    SynthText::new(TextConfig::from_model(model, cfg.seed ^ 0xDA7A).unwrap())
}

#[test]
fn nonprivate_loss_decreases() {
    let rt = Runtime::builtin();
    let mut cfg = base_cfg(Algorithm::NonPrivate);
    cfg.steps = 60;
    let gen = criteo_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let out = trainer.run_pctr(&gen).unwrap();
    let first: f64 = out.loss_history[..10].iter().sum::<f64>() / 10.0;
    let last: f64 = out.loss_history[out.loss_history.len() - 10..]
        .iter()
        .sum::<f64>()
        / 10.0;
    assert!(
        last < first - 0.01,
        "loss did not decrease: {first:.4} -> {last:.4}"
    );
    assert!(out.utility > 0.52, "AUC {}", out.utility);
}

#[test]
fn all_algorithms_run_and_grad_size_ordering_holds() {
    let rt = Runtime::builtin();
    let mut sizes = std::collections::HashMap::new();
    for algo in [
        Algorithm::DpSgd,
        Algorithm::DpAdaFest,
        Algorithm::DpAdaFestPlus,
        Algorithm::DpFest,
        Algorithm::ExpSelection,
    ] {
        let mut cfg = base_cfg(algo);
        cfg.tau = 5.0;
        cfg.fest_top_k = 1024;
        cfg.exp_select_m = 512;
        let gen = criteo_gen(&rt, &cfg);
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let out = trainer.run_pctr(&gen).unwrap();
        assert!(out.loss_history.iter().all(|l| l.is_finite()), "{algo:?}");
        assert!(out.utility.is_finite());
        sizes.insert(algo, out.emb_grad_coords_per_step);
    }
    let dense = sizes[&Algorithm::DpSgd];
    // every sparsity-preserving variant noises strictly fewer coordinates
    for algo in [
        Algorithm::DpAdaFest,
        Algorithm::DpAdaFestPlus,
        Algorithm::DpFest,
        Algorithm::ExpSelection,
    ] {
        assert!(
            sizes[&algo] < dense * 0.8,
            "{algo:?} size {} not < dense {dense}",
            sizes[&algo]
        );
    }
    // AdaFEST+ intersects with the FEST set, so it cannot exceed AdaFEST
    assert!(
        sizes[&Algorithm::DpAdaFestPlus] <= sizes[&Algorithm::DpAdaFest] * 1.05,
        "+: {} vs {}",
        sizes[&Algorithm::DpAdaFestPlus],
        sizes[&Algorithm::DpAdaFest]
    );
}

#[test]
fn dp_sgd_noises_every_embedding_coordinate() {
    let rt = Runtime::builtin();
    let cfg = base_cfg(Algorithm::DpSgd);
    let gen = criteo_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let emb_total = trainer.store.embedding_coords();
    let mut rng = Xoshiro256::seed_from(1);
    let batch = gen.batch(0, trainer.batch_size(), &mut rng);
    let stats = trainer.step_pctr(&batch).unwrap();
    assert_eq!(stats.emb_coords_noised, emb_total);
    assert_eq!(stats.dense_coords_noised, trainer.store.dense_coords());
}

#[test]
fn tau_monotonically_shrinks_gradient_size() {
    let rt = Runtime::builtin();
    let mut prev = f64::INFINITY;
    for tau in [0.5, 5.0, 50.0] {
        let mut cfg = base_cfg(Algorithm::DpAdaFest);
        cfg.tau = tau;
        let gen = criteo_gen(&rt, &cfg);
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let out = trainer.run_pctr(&gen).unwrap();
        assert!(
            out.emb_grad_coords_per_step <= prev * 1.1,
            "tau={tau}: {} > prev {prev}",
            out.emb_grad_coords_per_step
        );
        prev = out.emb_grad_coords_per_step;
    }
}

#[test]
fn frozen_embedding_is_untouched() {
    let rt = Runtime::builtin();
    let mut cfg = RunConfig::default();
    cfg.model = "nlu-tiny".into();
    cfg.algorithm = Algorithm::DpSgd;
    cfg.freeze_embedding = true;
    cfg.steps = 3;
    cfg.eval_batches = 2;
    let gen = text_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let emb_before = trainer
        .store
        .get("emb_table")
        .unwrap()
        .tensor
        .as_f32()
        .unwrap()
        .to_vec();
    let mut rng = Xoshiro256::seed_from(2);
    for _ in 0..3 {
        let b = gen.batch(trainer.batch_size(), &mut rng);
        let stats = trainer.step_text(&b).unwrap();
        assert_eq!(stats.emb_coords_noised, 0);
    }
    let emb_after = trainer
        .store
        .get("emb_table")
        .unwrap()
        .tensor
        .as_f32()
        .unwrap();
    assert_eq!(emb_before.as_slice(), emb_after);
}

#[test]
fn nlu_trains_artifact_free() {
    // the native transformer executor drives the full NLU pipeline with no
    // AOT artifacts: DP-AdaFEST selection sparsifies the vocabulary
    let rt = Runtime::builtin();
    let mut cfg = RunConfig::default();
    cfg.model = "nlu-tiny".into();
    cfg.algorithm = Algorithm::DpAdaFest;
    cfg.steps = 4;
    cfg.eval_batches = 2;
    cfg.tau = 2.0;
    let gen = text_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let out = trainer.run_text(&gen).unwrap();
    assert!(out.loss_history.iter().all(|l| l.is_finite()));
    assert!(out.utility.is_finite() && out.utility >= 0.0);
    assert!(out.reduction_factor > 1.0, "nlu-tiny: no reduction");
}

#[test]
fn streaming_protocol_runs_and_evals_future_days() {
    let rt = Runtime::builtin();
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-tiny".into();
    cfg.algorithm = Algorithm::DpAdaFestPlus;
    cfg.c2 = 0.5;
    cfg.steps = 36; // 2/day
    cfg.eval_batches = 4;
    cfg.streaming_period = 2;
    cfg.fest_top_k = 2048;
    let model = rt.manifest.model(&cfg.model).unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    let gen = SynthCriteo::new(CriteoConfig::new(vocabs, 9).with_drift());
    let trainer = Trainer::new(cfg, &rt).unwrap();
    let mut st = StreamingTrainer::new(trainer, 2);
    let out = st.run(&gen).unwrap();
    assert_eq!(out.per_day_auc.len(), 6);
    assert!(out.reselections >= 1);
    assert!(out.outcome.utility.is_finite());
}

// ---- artifact-only models: xla-gated leg ----

fn artifact_runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping xla leg: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    if !cfg!(feature = "xla") {
        eprintln!("skipping xla leg: artifacts present but built without --features xla");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime init"))
}

#[test]
fn xla_nlu_and_xlmr_train() {
    let Some(rt) = artifact_runtime() else { return };
    for model_name in ["nlu-roberta", "nlu-xlmr"] {
        let mut cfg = RunConfig::default();
        cfg.model = model_name.into();
        cfg.algorithm = Algorithm::DpAdaFest;
        cfg.steps = 4;
        cfg.eval_batches = 2;
        cfg.tau = 2.0;
        let gen = text_gen(&rt, &cfg);
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let out = trainer.run_text(&gen).unwrap();
        assert!(out.utility.is_finite() && out.utility >= 0.0);
        assert!(out.reduction_factor > 1.0, "{model_name}: no reduction");
    }
}

#[test]
fn xla_loraemb_model_trains_densely() {
    let Some(rt) = artifact_runtime() else { return };
    let mut cfg = RunConfig::default();
    cfg.model = "nlu-roberta-loraemb16".into();
    cfg.algorithm = Algorithm::DpSgd;
    cfg.steps = 3;
    cfg.eval_batches = 2;
    let gen = text_gen(&rt, &cfg);
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let emb_lora_coords = trainer.store.get("emb_lora_a").unwrap().num_elements();
    let out = trainer.run_text(&gen).unwrap();
    // dense noise on the LoRA-A factor every step
    assert!((out.emb_grad_coords_per_step - emb_lora_coords as f64).abs() < 1.0);
}
