//! Property-test suite locking down the blocked kernel subsystem
//! (`rust/src/kernels/`): every blocked, register-tiled kernel must be
//! **bit-identical** (`f32::to_bits` equality, never approximate) to the
//! naive scalar loop it retired, over random shapes and row pitches —
//! including dims that are not multiples of the 4×8 register tile, 0/1-
//! sized edges, pitch > width slack (which the kernels must not touch),
//! and the strided per-head column-slice layout the attention path uses.
//!
//! The in-test oracles below *are* the retired loops: one accumulation
//! chain per output element — init per `MatInit`, k terms in ascending
//! order, the documented `A == 0.0` skip — written as plain triple loops.
//! A second pass re-runs every comparison with the thread fan-out forced on
//! (`set_threads(4)`, `set_par_min_work(0)`): parallel output tiling must
//! not move a single bit.
//!
//! The SIMD backend (`--engine-kernel-backend simd`) deliberately
//! reassociates the k reduction chains (lane partials + a pairwise
//! horizontal sum — `src/kernels/simd.rs` module docs), so the second half
//! of this file holds it to a *documented tolerance* instead: every
//! element must be within `SIMD_MAX_ULP` ULPs of the scalar oracle, or
//! within the standard reassociated-summation error bound
//! `2·(k+1)·ε · Σ|terms|` with the magnitude Σ|terms| computed by an f64
//! oracle.  Pitch slack must still survive bit-for-bit, and on an
//! exhaustive {0,1}-operand grid (small-integer sums, exact under any
//! association) the SIMD backend must be bit-identical outright.

mod support;

use std::sync::{Mutex, MutexGuard};

use sparse_dp_emb::kernels::{self, gelu, KernelBackend, MatInit, MatShape, DEFAULT_PAR_MIN_WORK};
use sparse_dp_emb::proptest::{check, usize_in, CaseResult};
use sparse_dp_emb::util::rng::Xoshiro256;

/// The kernel threading knobs are process-wide; serialize the tests that
/// set them so each one observes the mode it configured.
fn config_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restore the default (serial, scalar) kernel configuration on drop,
/// panic included.
struct SerialOnDrop;
impl Drop for SerialOnDrop {
    fn drop(&mut self) {
        kernels::set_threads(1);
        kernels::set_par_min_work(DEFAULT_PAR_MIN_WORK);
        kernels::set_backend(KernelBackend::Scalar);
    }
}

// ---------------------------------------------------------------------------
// The retired scalar loops, as oracles
// ---------------------------------------------------------------------------

fn chain_land(out: &mut f32, acc: f32, init: &MatInit<'_>) {
    match init {
        MatInit::Accumulate => *out += acc,
        _ => *out = acc,
    }
}

fn chain_start(j: usize, init: &MatInit<'_>) -> f32 {
    match init {
        MatInit::Bias(b) => b[j],
        _ => 0.0,
    }
}

/// `C = A·B`: chain starts per init, k ascending, skip `A == 0.0`.
fn oracle_matmul(a: &[f32], b: &[f32], out: &mut [f32], sh: MatShape, init: &MatInit<'_>) {
    for i in 0..sh.m {
        for j in 0..sh.n {
            let mut acc = chain_start(j, init);
            for kk in 0..sh.k {
                let av = a[i * sh.ra + kk];
                if av != 0.0 {
                    acc += av * b[kk * sh.rb + j];
                }
            }
            chain_land(&mut out[i * sh.rc + j], acc, init);
        }
    }
}

/// `C = A·Bᵀ`: chain starts per init, k ascending, no skip.
fn oracle_matmul_bt(a: &[f32], b: &[f32], out: &mut [f32], sh: MatShape, init: &MatInit<'_>) {
    for i in 0..sh.m {
        for j in 0..sh.n {
            let mut acc = chain_start(j, init);
            for kk in 0..sh.k {
                acc += a[i * sh.ra + kk] * b[j * sh.rb + kk];
            }
            chain_land(&mut out[i * sh.rc + j], acc, init);
        }
    }
}

/// `C = Aᵀ·B`: chain starts per init, p ascending, skip `A == 0.0`.
fn oracle_matmul_at(a: &[f32], b: &[f32], out: &mut [f32], sh: MatShape, init: &MatInit<'_>) {
    for i in 0..sh.m {
        for j in 0..sh.n {
            let mut acc = chain_start(j, init);
            for p in 0..sh.k {
                let av = a[p * sh.ra + i];
                if av != 0.0 {
                    acc += av * b[p * sh.rb + j];
                }
            }
            chain_land(&mut out[i * sh.rc + j], acc, init);
        }
    }
}

/// The retired affine + separate GELU pass.
fn oracle_add_bias_gelu(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    pre: &mut [f32],
    post: &mut [f32],
    sh: MatShape,
) {
    oracle_matmul(x, w, pre, sh, &MatInit::Bias(bias));
    for i in 0..sh.m {
        for j in 0..sh.n {
            post[i * sh.rc + j] = gelu(pre[i * sh.rc + j]);
        }
    }
}

/// The retired attention softmax: scale while tracking the max, exp with a
/// running denominator, multiply by the reciprocal.
fn oracle_softmax_rows(x: &mut [f32], rows: usize, cols: usize, pitch: usize, scale: f32) {
    for r in 0..rows {
        let row = &mut x[r * pitch..r * pitch + cols];
        let mut mx = f32::NEG_INFINITY;
        for v in row.iter_mut() {
            *v *= scale;
            if *v > mx {
                mx = *v;
            }
        }
        let mut denom = 0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            denom += *v;
        }
        let inv = 1.0 / denom;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

fn oracle_softmax_rows_bwd(
    att: &[f32],
    d: &mut [f32],
    rows: usize,
    cols: usize,
    pitches: (usize, usize),
    scale: f32,
) {
    let (ra, rd) = pitches;
    for r in 0..rows {
        let arow = &att[r * ra..r * ra + cols];
        let drow = &mut d[r * rd..r * rd + cols];
        let mut dot = 0f32;
        for (&aw, &dw) in arow.iter().zip(drow.iter()) {
            dot += aw * dw;
        }
        for (dv, &aw) in drow.iter_mut().zip(arow) {
            *dv = aw * (*dv - dot) * scale;
        }
    }
}

// ---------------------------------------------------------------------------
// Random-case generation
// ---------------------------------------------------------------------------

/// A dim drawn to hit tile edges often: 0/1 edges, sub-tile, exact
/// multiples of MR/NR, and off-tile values.
fn dim(rng: &mut Xoshiro256) -> usize {
    const POOL: [usize; 12] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 12, 16, 17];
    POOL[rng.below(POOL.len() as u64) as usize]
}

/// Random data with exact zeros injected (the skip path) and slack filled
/// with garbage the kernels must preserve.
fn operand(rng: &mut Xoshiro256, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if rng.uniform() < 0.15 {
                0.0
            } else {
                (rng.gauss() * 1.5) as f32
            }
        })
        .collect()
}

fn rand_shape(rng: &mut Xoshiro256) -> MatShape {
    let (m, k, n) = (dim(rng), dim(rng), dim(rng));
    MatShape {
        m,
        k,
        n,
        ra: 0, // flavor-specific; filled by callers
        rb: 0,
        rc: n + usize_in(rng, 0, 3),
    }
}

fn rand_init(rng: &mut Xoshiro256, bias: &[f32]) -> (&'static str, MatInitOwned) {
    match rng.below(3) {
        0 => ("zero", MatInitOwned::Zero),
        1 => ("acc", MatInitOwned::Accumulate),
        _ => ("bias", MatInitOwned::Bias(bias.to_vec())),
    }
}

/// Owned stand-in for `MatInit` so a case can build it before borrowing.
enum MatInitOwned {
    Zero,
    Accumulate,
    Bias(Vec<f32>),
}

impl MatInitOwned {
    fn as_init(&self) -> MatInit<'_> {
        match self {
            MatInitOwned::Zero => MatInit::Zero,
            MatInitOwned::Accumulate => MatInit::Accumulate,
            MatInitOwned::Bias(b) => MatInit::Bias(b),
        }
    }
}

fn bits_eq(got: &[f32], want: &[f32], what: &str) -> CaseResult {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != w.to_bits() {
            return Err(format!(
                "{what}: bit mismatch at {i}: {g:?} vs {w:?} ({:#x} vs {:#x})",
                g.to_bits(),
                w.to_bits()
            ));
        }
    }
    Ok(())
}

/// Buffer length for `rows` rows at `pitch`, plus extra slack whose bits
/// must survive the call untouched.
fn buf_len(rows: usize, pitch: usize, cols: usize, slack: usize) -> usize {
    let need = if rows == 0 || cols == 0 { 0 } else { (rows - 1) * pitch + cols };
    need + slack
}

/// One full matmul-family case at the current thread configuration:
/// generate shapes/strides/operands, run kernel vs oracle on identical
/// output prefills, compare every bit (slack included).
fn matmul_family_case(rng: &mut Xoshiro256) -> CaseResult {
    let mut sh = rand_shape(rng);
    let flavor = rng.below(3);
    // logical widths of A/B rows per flavor, then random pitch slack
    let (wa, rows_a, wb, rows_b) = match flavor {
        0 => (sh.k, sh.m, sh.n, sh.k), // matmul: A (m×k), B (k×n)
        1 => (sh.k, sh.m, sh.k, sh.n), // bt: A (m×k), B (n×k)
        _ => (sh.m, sh.k, sh.n, sh.k), // at: A (k×m), B (k×n)
    };
    sh.ra = wa + usize_in(rng, 0, 3);
    sh.rb = wb + usize_in(rng, 0, 3);
    let a = operand(rng, buf_len(rows_a, sh.ra, wa, 2));
    let b = operand(rng, buf_len(rows_b, sh.rb, wb, 2));
    let bias = operand(rng, sh.n);
    let (init_name, owned) = rand_init(rng, &bias);
    let init = owned.as_init();

    let prefill = operand(rng, buf_len(sh.m, sh.rc, sh.n, 3));
    let mut got = prefill.clone();
    let mut want = prefill;
    match flavor {
        0 => {
            kernels::matmul(&a, &b, &mut got, sh, init);
            oracle_matmul(&a, &b, &mut want, sh, &init);
        }
        1 => {
            kernels::matmul_bt(&a, &b, &mut got, sh, init);
            oracle_matmul_bt(&a, &b, &mut want, sh, &init);
        }
        _ => {
            kernels::matmul_at(&a, &b, &mut got, sh, init);
            oracle_matmul_at(&a, &b, &mut want, sh, &init);
        }
    }
    bits_eq(&got, &want, &format!("flavor {flavor} init {init_name} {sh:?}"))
}

// ---------------------------------------------------------------------------
// The properties
// ---------------------------------------------------------------------------

#[test]
fn blocked_matmuls_bit_match_scalar_oracles() {
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    check("matmul family == scalar oracle (serial)", 400, matmul_family_case);
}

#[test]
fn threaded_tiling_bit_matches_scalar_oracles() {
    // the same property with the row fan-out forced on at every shape:
    // parallel output tiling must not reorder any accumulation chain
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(4);
    kernels::set_par_min_work(0);
    check("matmul family == scalar oracle (threaded)", 400, matmul_family_case);
}

#[test]
fn add_bias_gelu_bit_matches_affine_plus_gelu() {
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    for threads in [1usize, 4] {
        kernels::set_threads(threads);
        kernels::set_par_min_work(if threads > 1 { 0 } else { DEFAULT_PAR_MIN_WORK });
        check("add_bias_gelu == affine ∘ gelu", 200, |rng| {
            let mut sh = rand_shape(rng);
            sh.ra = sh.k + usize_in(rng, 0, 2);
            sh.rb = sh.n + usize_in(rng, 0, 2);
            let x = operand(rng, buf_len(sh.m, sh.ra, sh.k, 2));
            let w = operand(rng, buf_len(sh.k, sh.rb, sh.n, 2));
            let bias = operand(rng, sh.n);
            let prefill_a = operand(rng, buf_len(sh.m, sh.rc, sh.n, 2));
            let prefill_g = operand(rng, buf_len(sh.m, sh.rc, sh.n, 2));
            let (mut got_a, mut got_g) = (prefill_a.clone(), prefill_g.clone());
            let (mut want_a, mut want_g) = (prefill_a, prefill_g);
            kernels::add_bias_gelu(&x, &w, &bias, &mut got_a, &mut got_g, sh);
            oracle_add_bias_gelu(&x, &w, &bias, &mut want_a, &mut want_g, sh);
            bits_eq(&got_a, &want_a, &format!("pre-activations {sh:?}"))?;
            bits_eq(&got_g, &want_g, &format!("gelu outputs {sh:?}"))
        });
    }
}

#[test]
fn softmax_rows_bit_match_scalar_oracle() {
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    for threads in [1usize, 3] {
        kernels::set_threads(threads);
        kernels::set_par_min_work(if threads > 1 { 0 } else { DEFAULT_PAR_MIN_WORK });
        check("softmax fwd/bwd == scalar oracle", 200, |rng| {
            let rows = dim(rng);
            let cols = dim(rng).max(1); // an empty row has no softmax
            let pitch = cols + usize_in(rng, 0, 3);
            let scale = (0.2 + rng.uniform() * 2.0) as f32;
            let x0 = operand(rng, buf_len(rows, pitch, cols, 2));
            let mut got = x0.clone();
            let mut want = x0;
            kernels::softmax_rows(&mut got, rows, cols, pitch, scale);
            oracle_softmax_rows(&mut want, rows, cols, pitch, scale);
            bits_eq(&got, &want, &format!("softmax fwd {rows}x{cols}+{pitch}"))?;

            // backward over the forward's probabilities
            let rd = cols + usize_in(rng, 0, 2);
            let d0 = operand(rng, buf_len(rows, rd, cols, 2));
            let mut dg = d0.clone();
            let mut dw = d0;
            kernels::softmax_rows_bwd(&got, &mut dg, rows, cols, pitch, rd, scale);
            oracle_softmax_rows_bwd(&got, &mut dw, rows, cols, (pitch, rd), scale);
            bits_eq(&dg, &dw, &format!("softmax bwd {rows}x{cols}"))
        });
    }
}

#[test]
fn attention_head_slices_bit_match_oracle() {
    // The exact strided layout the transformer uses: per-head column
    // slices of (t, d) buffers, pitch d, width d/heads — scores, context,
    // and the dv/dk transposed products, serial and threaded.
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    for threads in [1usize, 4] {
        kernels::set_threads(threads);
        kernels::set_par_min_work(if threads > 1 { 0 } else { DEFAULT_PAR_MIN_WORK });
        check("attention head-slice kernels", 120, |rng| {
            let t = usize_in(rng, 1, 9);
            let heads = usize_in(rng, 1, 3);
            let dh = usize_in(rng, 1, 9);
            let d = heads * dh;
            let q = operand(rng, t * d);
            let k = operand(rng, t * d);
            let v = operand(rng, t * d);
            for head in 0..heads {
                let off = head * dh;
                let wide = MatShape { m: t, k: dh, n: t, ra: d, rb: d, rc: t };
                let thin = MatShape { m: t, k: t, n: dh, ra: t, rb: d, rc: d };
                let mut att_g = vec![0f32; t * t];
                let mut att_w = vec![0f32; t * t];
                kernels::matmul_bt(&q[off..], &k[off..], &mut att_g, wide, MatInit::Zero);
                oracle_matmul_bt(&q[off..], &k[off..], &mut att_w, wide, &MatInit::Zero);
                bits_eq(&att_g, &att_w, "head scores")?;

                let mut ctx_g = vec![0f32; t * d];
                let mut ctx_w = vec![0f32; t * d];
                kernels::matmul(&att_g, &v[off..], &mut ctx_g[off..], thin, MatInit::Zero);
                oracle_matmul(&att_w, &v[off..], &mut ctx_w[off..], thin, &MatInit::Zero);
                bits_eq(&ctx_g, &ctx_w, "head context")?;

                let mut dv_g = vec![0f32; t * d];
                let mut dv_w = vec![0f32; t * d];
                kernels::matmul_at(&att_g, &q[off..], &mut dv_g[off..], thin, MatInit::Zero);
                oracle_matmul_at(&att_w, &q[off..], &mut dv_w[off..], thin, &MatInit::Zero);
                bits_eq(&dv_g, &dv_w, "head transposed product")?;
            }
            Ok(())
        });
    }
}

#[test]
fn zero_and_unit_dim_grid_is_exact() {
    // exhaustive 0/1/edge grid — the shapes property sampling might miss
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    let mut rng = Xoshiro256::seed_from(0xED6E);
    for m in [0usize, 1, 2, 5] {
        for k in [0usize, 1, 3] {
            for n in [0usize, 1, 2, 9] {
                let sh = MatShape::packed(m, k, n);
                let a = operand(&mut rng, m * k);
                let b = operand(&mut rng, k * n);
                let bias = operand(&mut rng, n);
                for owned in [
                    MatInitOwned::Zero,
                    MatInitOwned::Accumulate,
                    MatInitOwned::Bias(bias.clone()),
                ] {
                    let init = owned.as_init();
                    let prefill = operand(&mut rng, m * n);
                    let mut got = prefill.clone();
                    let mut want = prefill;
                    kernels::matmul(&a, &b, &mut got, sh, init);
                    oracle_matmul(&a, &b, &mut want, sh, &init);
                    bits_eq(&got, &want, &format!("grid {m}x{k}x{n}"))
                        .unwrap_or_else(|e| panic!("{e}"));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The SIMD backend, against the same oracles, within documented tolerance
// ---------------------------------------------------------------------------

/// Rounding noise allowed even where the f64 magnitude bound is tiny (the
/// `|ULP| ≤ 4` arm of the documented tolerance).
const SIMD_MAX_ULP: u64 = 4;

/// The documented SIMD tolerance: within `SIMD_MAX_ULP` ULPs of the scalar
/// oracle, OR within the standard reassociated-summation bound
/// `2·(terms+1)·ε·mag` where `mag` comes from an f64 magnitude oracle.
fn simd_close(got: f32, want: f32, terms: usize, mag: f64, what: &str) -> CaseResult {
    let Some(d) = support::ulp::ulp_distance(got, want) else {
        return Err(format!("{what}: {got:e} vs {want:e}: one side is NaN"));
    };
    if d <= SIMD_MAX_ULP {
        return Ok(());
    }
    let bound = 2.0 * (terms as f64 + 1.0) * f32::EPSILON as f64 * mag;
    let diff = (got as f64 - want as f64).abs();
    if diff <= bound {
        return Ok(());
    }
    Err(format!(
        "{what}: {got:e} vs {want:e}: {d} ULPs, |diff| {diff:e} > bound {bound:e} \
         (k={terms}, mag={mag:e})"
    ))
}

/// f64 magnitude oracle for the matmul family: per logical cell,
/// `Σ_k |aᵢₖ·bₖⱼ|` plus the |chain start| — the scale the relative-error
/// bound is stated against.  `flavor` matches `matmul_family_case`.
fn mag_matmul_family(
    a: &[f32],
    b: &[f32],
    prefill: &[f32],
    sh: MatShape,
    flavor: u64,
    init: &MatInit<'_>,
) -> Vec<f64> {
    let mut mag = vec![0f64; sh.m * sh.n];
    for i in 0..sh.m {
        for j in 0..sh.n {
            let mut m = match init {
                MatInit::Bias(bb) => bb[j].abs() as f64,
                MatInit::Accumulate => prefill[i * sh.rc + j].abs() as f64,
                _ => 0.0,
            };
            for kk in 0..sh.k {
                let (av, bv) = match flavor {
                    0 => (a[i * sh.ra + kk], b[kk * sh.rb + j]),
                    1 => (a[i * sh.ra + kk], b[j * sh.rb + kk]),
                    _ => (a[kk * sh.ra + i], b[kk * sh.rb + j]),
                };
                m += (av as f64 * bv as f64).abs();
            }
            mag[i * sh.n + j] = m;
        }
    }
    mag
}

/// Tolerance comparison over a pitched output buffer: every logical cell
/// within `simd_close`, every slack/pitch word untouched bit-for-bit.
fn simd_compare_mat(
    got: &[f32],
    want: &[f32],
    sh: MatShape,
    terms: usize,
    mag: &[f64],
    what: &str,
) -> CaseResult {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    let mut logical = vec![false; got.len()];
    for i in 0..sh.m {
        for j in 0..sh.n {
            let idx = i * sh.rc + j;
            logical[idx] = true;
            simd_close(got[idx], want[idx], terms, mag[i * sh.n + j], what)?;
        }
    }
    for (idx, l) in logical.iter().enumerate() {
        if !l && got[idx].to_bits() != want[idx].to_bits() {
            return Err(format!("{what}: slack/pitch word {idx} touched"));
        }
    }
    Ok(())
}

/// One matmul-family case with the SIMD backend live (set by the caller):
/// same generation as `matmul_family_case`, tolerance comparison instead
/// of bit equality.
fn simd_matmul_family_case(rng: &mut Xoshiro256) -> CaseResult {
    let mut sh = rand_shape(rng);
    let flavor = rng.below(3);
    let (wa, rows_a, wb, rows_b) = match flavor {
        0 => (sh.k, sh.m, sh.n, sh.k),
        1 => (sh.k, sh.m, sh.k, sh.n),
        _ => (sh.m, sh.k, sh.n, sh.k),
    };
    sh.ra = wa + usize_in(rng, 0, 3);
    sh.rb = wb + usize_in(rng, 0, 3);
    let a = operand(rng, buf_len(rows_a, sh.ra, wa, 2));
    let b = operand(rng, buf_len(rows_b, sh.rb, wb, 2));
    let bias = operand(rng, sh.n);
    let (init_name, owned) = rand_init(rng, &bias);
    let init = owned.as_init();

    let prefill = operand(rng, buf_len(sh.m, sh.rc, sh.n, 3));
    let mag = mag_matmul_family(&a, &b, &prefill, sh, flavor, &init);
    let mut got = prefill.clone();
    let mut want = prefill;
    match flavor {
        0 => {
            kernels::matmul(&a, &b, &mut got, sh, init);
            oracle_matmul(&a, &b, &mut want, sh, &init);
        }
        1 => {
            kernels::matmul_bt(&a, &b, &mut got, sh, init);
            oracle_matmul_bt(&a, &b, &mut want, sh, &init);
        }
        _ => {
            kernels::matmul_at(&a, &b, &mut got, sh, init);
            oracle_matmul_at(&a, &b, &mut want, sh, &init);
        }
    }
    let what = format!("simd flavor {flavor} init {init_name} {sh:?}");
    simd_compare_mat(&got, &want, sh, sh.k, &mag, &what)
}

#[test]
fn simd_matmuls_match_scalar_oracles_within_tolerance() {
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    kernels::set_backend(KernelBackend::Simd);
    check("matmul family ~ scalar oracle (simd, serial)", 400, simd_matmul_family_case);
}

#[test]
fn simd_threaded_tiling_matches_within_tolerance() {
    // lane parallelism composes with the row fan-out: rows are partitioned
    // across threads, each thread runs the same lane-parallel chains
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(4);
    kernels::set_par_min_work(0);
    kernels::set_backend(KernelBackend::Simd);
    check("matmul family ~ scalar oracle (simd, threaded)", 400, simd_matmul_family_case);
}

#[test]
fn simd_add_bias_gelu_matches_within_tolerance() {
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    kernels::set_backend(KernelBackend::Simd);
    check("add_bias_gelu ~ affine ∘ gelu (simd)", 200, |rng| {
        let mut sh = rand_shape(rng);
        sh.ra = sh.k + usize_in(rng, 0, 2);
        sh.rb = sh.n + usize_in(rng, 0, 2);
        let x = operand(rng, buf_len(sh.m, sh.ra, sh.k, 2));
        let w = operand(rng, buf_len(sh.k, sh.rb, sh.n, 2));
        let bias = operand(rng, sh.n);
        let prefill_a = operand(rng, buf_len(sh.m, sh.rc, sh.n, 2));
        let prefill_g = operand(rng, buf_len(sh.m, sh.rc, sh.n, 2));
        let mag = mag_matmul_family(&x, &w, &prefill_a, sh, 0, &MatInit::Bias(&bias));
        let (mut got_a, mut got_g) = (prefill_a.clone(), prefill_g.clone());
        let (mut want_a, mut want_g) = (prefill_a, prefill_g);
        kernels::add_bias_gelu(&x, &w, &bias, &mut got_a, &mut got_g, sh);
        oracle_add_bias_gelu(&x, &w, &bias, &mut want_a, &mut want_g, sh);
        simd_compare_mat(&got_a, &want_a, sh, sh.k, &mag, "simd pre-activations")?;
        // gelu is 1-Lipschitz up to a small constant (sup|gelu'| < 2), and
        // both backends evaluate the same gelu code on their own
        // pre-activations — so the post magnitude is a scaled pre magnitude
        let mag_post: Vec<f64> = mag.iter().map(|m| 2.0 * m).collect();
        simd_compare_mat(&got_g, &want_g, sh, sh.k, &mag_post, "simd gelu outputs")
    });
}

#[test]
fn simd_softmax_rows_match_within_tolerance() {
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    kernels::set_backend(KernelBackend::Simd);
    check("softmax fwd/bwd ~ scalar oracle (simd)", 200, |rng| {
        let rows = dim(rng);
        let cols = dim(rng).max(1);
        let pitch = cols + usize_in(rng, 0, 3);
        let scale = (0.2 + rng.uniform() * 2.0) as f32;
        let x0 = operand(rng, buf_len(rows, pitch, cols, 2));
        let mut got = x0.clone();
        let mut want = x0;
        kernels::softmax_rows(&mut got, rows, cols, pitch, scale);
        oracle_softmax_rows(&mut want, rows, cols, pitch, scale);
        // scale/max/exp are elementwise-identical across backends; only the
        // denominator sum reassociates, so each probability carries a
        // relative error of at most the cols-term summation bound
        for r in 0..rows {
            for c in 0..cols {
                let idx = r * pitch + c;
                simd_close(got[idx], want[idx], cols, want[idx].abs() as f64, "simd softmax fwd")?;
            }
        }
        let in_row = |idx: usize| idx / pitch.max(1) < rows && idx % pitch.max(1) < cols;
        for idx in 0..got.len() {
            if !in_row(idx) && got[idx].to_bits() != want[idx].to_bits() {
                return Err(format!("simd softmax fwd: pitch slack word {idx} touched"));
            }
        }

        // backward over the *oracle* probabilities on both sides, so the
        // comparison isolates the kernel (compounding across ops is the
        // e2e suite's job — tests/simd.rs)
        let rd = cols + usize_in(rng, 0, 2);
        let d0 = operand(rng, buf_len(rows, rd, cols, 2));
        let mut dg = d0.clone();
        let mut dw = d0.clone();
        kernels::softmax_rows_bwd(&want, &mut dg, rows, cols, pitch, rd, scale);
        oracle_softmax_rows_bwd(&want, &mut dw, rows, cols, (pitch, rd), scale);
        for r in 0..rows {
            // only the att·d dot reassociates; its error lands on element j
            // scaled by att_j·scale, plus the |want| re-rounding the ULP
            // arm absorbs
            let mut sum_ad = 0f64;
            for c in 0..cols {
                sum_ad += (want[r * pitch + c] as f64 * d0[r * rd + c] as f64).abs();
            }
            for c in 0..cols {
                let aj = want[r * pitch + c].abs() as f64 * scale as f64;
                let mag = aj * sum_ad + dw[r * rd + c].abs() as f64;
                simd_close(dg[r * rd + c], dw[r * rd + c], cols, mag, "simd softmax bwd")?;
            }
        }
        Ok(())
    });
}

#[test]
fn simd_attention_head_slices_match_within_tolerance() {
    // the strided per-head column-slice layout, SIMD backend: scores via
    // the k-vectorized bt kernel (tolerance), context and the transposed
    // product via the j-vectorized kernels fed identical inputs on both
    // sides (so each kernel is judged in isolation)
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    kernels::set_backend(KernelBackend::Simd);
    check("attention head-slice kernels (simd)", 120, |rng| {
        let t = usize_in(rng, 1, 9);
        let heads = usize_in(rng, 1, 3);
        let dh = usize_in(rng, 1, 9);
        let d = heads * dh;
        let q = operand(rng, t * d);
        let k = operand(rng, t * d);
        let v = operand(rng, t * d);
        for head in 0..heads {
            let off = head * dh;
            let wide = MatShape { m: t, k: dh, n: t, ra: d, rb: d, rc: t };
            let thin = MatShape { m: t, k: t, n: dh, ra: t, rb: d, rc: d };
            let zeros = vec![0f32; t * t];
            let mag = mag_matmul_family(&q[off..], &k[off..], &zeros, wide, 1, &MatInit::Zero);
            let mut att_g = vec![0f32; t * t];
            let mut att_w = vec![0f32; t * t];
            kernels::matmul_bt(&q[off..], &k[off..], &mut att_g, wide, MatInit::Zero);
            oracle_matmul_bt(&q[off..], &k[off..], &mut att_w, wide, &MatInit::Zero);
            simd_compare_mat(&att_g, &att_w, wide, wide.k, &mag, "simd head scores")?;

            let zeros_td = vec![0f32; t * d];
            let mag = mag_matmul_family(&att_w, &v[off..], &zeros_td, thin, 0, &MatInit::Zero);
            let mut ctx_g = vec![0f32; t * d];
            let mut ctx_w = vec![0f32; t * d];
            kernels::matmul(&att_w, &v[off..], &mut ctx_g[off..], thin, MatInit::Zero);
            oracle_matmul(&att_w, &v[off..], &mut ctx_w[off..], thin, &MatInit::Zero);
            simd_compare_mat(&ctx_g[off..], &ctx_w[off..], thin, thin.k, &mag, "simd context")?;

            let mag = mag_matmul_family(&att_w, &q[off..], &zeros_td, thin, 2, &MatInit::Zero);
            let mut dv_g = vec![0f32; t * d];
            let mut dv_w = vec![0f32; t * d];
            kernels::matmul_at(&att_w, &q[off..], &mut dv_g[off..], thin, MatInit::Zero);
            oracle_matmul_at(&att_w, &q[off..], &mut dv_w[off..], thin, &MatInit::Zero);
            simd_compare_mat(&dv_g[off..], &dv_w[off..], thin, thin.k, &mag, "simd dv")?;
        }
        Ok(())
    });
}

#[test]
fn simd_zero_one_grid_is_bit_exact() {
    // {0,1} operands make every chain a sum of small non-negative integers
    // — exact in f32 under ANY association, so here the SIMD backend owes
    // full bit equality, lane reassociation and all.  Dims cross the
    // 8-lane width (9, 17) and the register tile (4×8).
    let _guard = config_lock();
    let _restore = SerialOnDrop;
    kernels::set_threads(1);
    kernels::set_backend(KernelBackend::Simd);
    let binary = |rng: &mut Xoshiro256, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.below(2) as f32).collect()
    };
    let mut rng = Xoshiro256::seed_from(0x51D0);
    for m in [0usize, 1, 2, 5, 9] {
        for k in [0usize, 1, 3, 8, 17] {
            for n in [0usize, 1, 2, 9] {
                let a = binary(&mut rng, m * k);
                let b01 = binary(&mut rng, k * n);
                let bias = binary(&mut rng, n);
                let bt_b = binary(&mut rng, n * k);
                let at_a = binary(&mut rng, k * m);
                for owned in [
                    MatInitOwned::Zero,
                    MatInitOwned::Accumulate,
                    MatInitOwned::Bias(bias.clone()),
                ] {
                    let init = owned.as_init();
                    let prefill = binary(&mut rng, m * n);
                    let what = format!("simd 0/1 grid {m}x{k}x{n}");

                    let (mut got, mut want) = (prefill.clone(), prefill.clone());
                    let sh = MatShape::packed(m, k, n);
                    kernels::matmul(&a, &b01, &mut got, sh, init);
                    oracle_matmul(&a, &b01, &mut want, sh, &init);
                    bits_eq(&got, &want, &what).unwrap_or_else(|e| panic!("{e}"));

                    let init = owned.as_init();
                    let (mut got, mut want) = (prefill.clone(), prefill.clone());
                    let sh = MatShape { m, k, n, ra: k, rb: k, rc: n };
                    kernels::matmul_bt(&a, &bt_b, &mut got, sh, init);
                    oracle_matmul_bt(&a, &bt_b, &mut want, sh, &init);
                    bits_eq(&got, &want, &format!("{what} bt")).unwrap_or_else(|e| panic!("{e}"));

                    let init = owned.as_init();
                    let (mut got, mut want) = (prefill.clone(), prefill);
                    let sh = MatShape { m, k, n, ra: m, rb: n, rc: n };
                    kernels::matmul_at(&at_a, &b01, &mut got, sh, init);
                    oracle_matmul_at(&at_a, &b01, &mut want, sh, &init);
                    bits_eq(&got, &want, &format!("{what} at")).unwrap_or_else(|e| panic!("{e}"));
                }
            }
        }
    }
}
