//! Property-based tests over coordinator invariants (DESIGN.md §7), using
//! the in-repo seeded-case harness (`sparse_dp_emb::proptest`).

use sparse_dp_emb::accounting::{compose_sigmas, Accountant};
use sparse_dp_emb::data::PctrBatch;
use sparse_dp_emb::filtering::{ContributionMap, SurvivorSet};
use sparse_dp_emb::metrics::auc;
use sparse_dp_emb::proptest::{check, ensure, f64_in, gauss_vec, usize_in};
use sparse_dp_emb::sparse::{
    add_row_noise, survivors_sparse, DenseState, Optimizer, RowSparseGrad,
};
use sparse_dp_emb::util::rng::Xoshiro256;

#[test]
fn prop_sparse_update_equals_dense_update() {
    check("sparse == dense optimizer step", 60, |rng| {
        let rows = usize_in(rng, 4, 60);
        let dim = usize_in(rng, 1, 12);
        let nnz = usize_in(rng, 1, rows);
        let mut g = RowSparseGrad::new(rows, dim);
        for _ in 0..nnz * 2 {
            let r = usize_in(rng, 0, rows - 1) as u32;
            g.add_row(r, &gauss_vec(rng, dim, 1.0));
        }
        let lr = f64_in(rng, 0.001, 0.5) as f32;
        let opt = Optimizer::sgd(lr);
        let init = gauss_vec(rng, rows * dim, 1.0);
        let mut a = init.clone();
        let mut b = init;
        opt.sparse_step(&mut a, &g, &mut DenseState::default());
        opt.dense_step(&mut b, &g.to_dense(), &mut DenseState::default());
        for (x, y) in a.iter().zip(&b) {
            if (x - y).abs() > 1e-5 {
                return Err(format!("mismatch {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_retain_then_densify_matches_mask() {
    check("retain_rows == dense mask", 60, |rng| {
        let rows = usize_in(rng, 4, 100);
        let dim = usize_in(rng, 1, 6);
        let mut g = RowSparseGrad::new(rows, dim);
        for _ in 0..usize_in(rng, 1, 40) {
            g.add_row(usize_in(rng, 0, rows - 1) as u32, &gauss_vec(rng, dim, 1.0));
        }
        let keep_mod = usize_in(rng, 1, 5) as u32;
        let dense_before = g.to_dense();
        g.retain_rows(|r| r % keep_mod == 0);
        let dense_after = g.to_dense();
        for r in 0..rows as u32 {
            for k in 0..dim {
                let want = if r % keep_mod == 0 {
                    dense_before[r as usize * dim + k]
                } else {
                    0.0
                };
                if (dense_after[r as usize * dim + k] - want).abs() > 1e-6 {
                    return Err(format!("row {r} wrong after retain"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_row_noise_preserves_support() {
    check("row noise touches exactly the stored rows", 40, |rng| {
        let rows = usize_in(rng, 10, 200);
        let dim = usize_in(rng, 1, 8);
        let mut g = RowSparseGrad::new(rows, dim);
        let nnz = usize_in(rng, 1, 9.min(rows));
        for i in 0..nnz {
            g.add_row((i * (rows / nnz)) as u32, &vec![0f32; dim]);
        }
        let before = g.nnz_rows();
        add_row_noise(&mut g, 1.0, rng);
        ensure(g.nnz_rows() == before, "support changed")?;
        let dense = g.to_dense();
        let nz_rows = (0..rows)
            .filter(|&r| dense[r * dim..(r + 1) * dim].iter().any(|&v| v != 0.0))
            .count();
        ensure(nz_rows == before, format!("{nz_rows} noisy rows vs {before}"))
    });
}

#[test]
fn prop_contribution_map_mass_bounded_by_c1_times_batch() {
    // each example's clipped indicator has l2 norm <= C1, hence l1 mass
    // <= C1 * sqrt(u) <= C1 * sqrt(F); total <= B * C1 * sqrt(F)
    check("contribution mass bound", 50, |rng| {
        let b = usize_in(rng, 1, 40);
        let f = usize_in(rng, 1, 12);
        let c = usize_in(rng, 4, 300);
        let c1 = f64_in(rng, 0.1, 10.0);
        let examples: Vec<Vec<u32>> = (0..b)
            .map(|_| (0..f).map(|_| usize_in(rng, 0, c - 1) as u32).collect())
            .collect();
        let map = ContributionMap::from_batch(&examples, c, c1);
        let bound = b as f64 * c1 * (f as f64).sqrt() + 1e-6;
        ensure(
            map.total_mass() <= bound,
            format!("mass {} > bound {bound}", map.total_mass()),
        )
    });
}

#[test]
fn prop_survivors_subset_and_tau_monotone() {
    check("survivor count monotone in tau (shared noise)", 40, |rng| {
        let c = usize_in(rng, 100, 5000);
        let nnz = usize_in(rng, 0, 50.min(c / 2));
        let mut ids: Vec<u32> = (0..nnz).map(|_| usize_in(rng, 0, c - 1) as u32).collect();
        ids.sort_unstable();
        ids.dedup();
        let nonzero: Vec<(u32, f32)> =
            ids.iter().map(|&i| (i, f64_in(rng, 0.5, 20.0) as f32)).collect();
        let seed = rng.next_u64();
        let mut counts = Vec::new();
        for tau in [0.0, 2.0, 8.0] {
            let mut r = Xoshiro256::seed_from(seed);
            let (s, _) = survivors_sparse(&nonzero, c, 1.0, 1.0, tau, &mut r);
            // ids unique & in range
            let mut u = s.clone();
            u.dedup();
            if u.len() != s.len() || s.iter().any(|&i| i as usize >= c) {
                return Err("invalid survivor ids".into());
            }
            counts.push(s.len());
        }
        ensure(
            counts[0] >= counts[1] && counts[1] >= counts[2],
            format!("not monotone: {counts:?}"),
        )
    });
}

#[test]
fn prop_survivor_intersection_is_subset() {
    check("adafest+ set ⊆ both parents", 50, |rng| {
        let n = usize_in(rng, 0, 200);
        let mut a: Vec<u32> = (0..n).map(|_| usize_in(rng, 0, 999) as u32).collect();
        let mut b: Vec<u32> = (0..n).map(|_| usize_in(rng, 0, 999) as u32).collect();
        a.sort_unstable();
        a.dedup();
        b.sort_unstable();
        b.dedup();
        let sa = SurvivorSet::from_sorted(a.clone());
        let sb = SurvivorSet::from_sorted(b.clone());
        let i = sa.intersect(&sb);
        for &x in i.ids() {
            if !sa.contains(x) || !sb.contains(x) {
                return Err(format!("{x} not in both parents"));
            }
        }
        // and nothing common is missing
        for &x in &a {
            if sb.contains(x) && !i.contains(x) {
                return Err(format!("{x} missing from intersection"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_accountant_epsilon_monotone() {
    // smoke-scale grid (PLD is expensive): monotone in T and in 1/sigma
    let e1 = Accountant::new(1.0, 0.02, 50).epsilon(1e-5);
    let e2 = Accountant::new(1.0, 0.02, 200).epsilon(1e-5);
    let e3 = Accountant::new(1.5, 0.02, 200).epsilon(1e-5);
    assert!(e2 > e1 && e3 < e2, "e1={e1} e2={e2} e3={e3}");
}

#[test]
fn prop_compose_sigmas_bounds() {
    check("sigma_eff < min(sigma1, sigma2) and symmetric", 100, |rng| {
        let s1 = f64_in(rng, 0.1, 50.0);
        let s2 = f64_in(rng, 0.1, 50.0);
        let eff = compose_sigmas(s1, s2);
        ensure(eff < s1.min(s2), format!("eff {eff} >= min({s1},{s2})"))?;
        ensure(
            (compose_sigmas(s2, s1) - eff).abs() < 1e-12,
            "not symmetric",
        )
    });
}

#[test]
fn prop_auc_invariant_to_monotone_transform() {
    check("AUC invariant under monotone score transform", 40, |rng| {
        let n = usize_in(rng, 10, 200);
        let scores: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let labels: Vec<f32> = (0..n).map(|_| (rng.below(2)) as f32).collect();
        if labels.iter().all(|&l| l == 0.0) || labels.iter().all(|&l| l == 1.0) {
            return Ok(()); // degenerate, AUC undefined
        }
        let a1 = auc(&scores, &labels);
        let transformed: Vec<f32> = scores.iter().map(|&s| (s * 0.3).tanh() * 5.0 + 2.0).collect();
        let a2 = auc(&transformed, &labels);
        ensure((a1 - a2).abs() < 1e-9, format!("{a1} vs {a2}"))
    });
}

#[test]
fn prop_batch_activated_rows_within_offsets() {
    check("activated rows land in the right table range", 50, |rng| {
        let nf = usize_in(rng, 1, 8);
        let vocabs: Vec<usize> = (0..nf).map(|_| usize_in(rng, 2, 50)).collect();
        let mut offsets = vec![0usize];
        for v in &vocabs[..nf - 1] {
            let last = *offsets.last().unwrap();
            offsets.push(last + v);
        }
        let bsz = usize_in(rng, 1, 16);
        let cat: Vec<i32> = (0..bsz * nf)
            .map(|i| usize_in(rng, 0, vocabs[i % nf] - 1) as i32)
            .collect();
        let batch = PctrBatch {
            batch_size: bsz,
            num_features: nf,
            num_numeric: 13,
            cat,
            num: vec![0.0; bsz * 13],
            y: vec![0.0; bsz],
        };
        let rows = batch.activated_rows(&offsets);
        for ex in &rows {
            for (f, &r) in ex.iter().enumerate() {
                let lo = offsets[f] as u32;
                let hi = lo + vocabs[f] as u32;
                if r < lo || r >= hi {
                    return Err(format!("row {r} outside table {f} [{lo},{hi})"));
                }
            }
        }
        Ok(())
    });
}
