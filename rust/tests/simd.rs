//! End-to-end acceptance of the opt-in SIMD kernel backend
//! (`--engine-kernel-backend simd`):
//!
//! * **loss-trajectory tolerance** against the scalar reference on both
//!   workloads: lane reassociation moves individual f32 bits, so the bar
//!   is "same training run to engineering precision" — finite everywhere,
//!   tiny relative gap at step 0, bounded per-step and mean relative gaps
//!   over the run (caps documented inline, on DP-SGD whose clip/noise/
//!   update path is continuous in the gradients — DP-AdaFEST's hard
//!   selection threshold can legitimately flip a coordinate at a tie
//!   boundary, so its cross-backend bar lives with the kernel-level suite
//!   in `tests/kernels.rs` and the *within*-backend equalities below);
//! * **sync == async == multi-process, bitwise, at the SIMD backend**: the
//!   concurrency invariants (docs/CONCURRENCY.md) are kernel-independent —
//!   every path runs the same kernel sequence — so with both sides on
//!   `simd` the outcomes and final parameters must still match
//!   bit-for-bit, including across process boundaries;
//! * **telemetry**: the run summary labels which backend actually ran;
//! * **knob scoping** (the PR's bugfix): `Trainer::new` / `engine::run`
//!   scope `kernel_threads` and `kernel_backend` to the run, restoring the
//!   prior process-wide values on exit — a threaded SIMD run followed by a
//!   default run leaves the globals at their defaults.
//!
//! The kernel threading/backend knobs are process-wide, so every test here
//! takes `config_lock()` — two concurrent runs wanting different backends
//! would clobber each other.

mod support;

use std::sync::{Mutex, MutexGuard};

use support::{
    assert_outcomes_identical, assert_params_identical, gen_cfg, text_cfg, tiny_cfg, tiny_nlu_cfg,
    use_cli_actor_exe,
};

use sparse_dp_emb::coordinator::{Algorithm, Trainer};
use sparse_dp_emb::data::{SynthCriteo, SynthText};
use sparse_dp_emb::engine;
use sparse_dp_emb::kernels::{self, KernelBackend};
use sparse_dp_emb::runtime::Runtime;

fn config_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The trajectory bar: equal lengths, everything finite, and relative
/// gaps small — ≤ 1% at step 0 (one forward pass of reassociation), ≤ 20%
/// at any single step (divergence compounds through the weights), ≤ 5% on
/// average over the run.
fn assert_trajectories_close(scalar: &[f64], simd: &[f64], what: &str) {
    assert_eq!(scalar.len(), simd.len(), "{what}: step count");
    assert!(!scalar.is_empty(), "{what}: empty trajectory");
    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
    let mut sum = 0.0;
    for (i, (&s, &v)) in scalar.iter().zip(simd).enumerate() {
        assert!(s.is_finite() && v.is_finite(), "{what}: non-finite loss at step {i}");
        let r = rel(s, v);
        assert!(r <= 0.20, "{what}: step {i} relative gap {r:.4} > 0.20 ({s} vs {v})");
        sum += r;
    }
    let step0 = rel(scalar[0], simd[0]);
    assert!(step0 <= 0.01, "{what}: step-0 relative gap {step0:.5} > 0.01");
    let mean = sum / scalar.len() as f64;
    assert!(mean <= 0.05, "{what}: mean relative gap {mean:.4} > 0.05");
}

#[test]
fn simd_loss_trajectory_tracks_scalar_on_pctr() {
    let _guard = config_lock();
    let rt = Runtime::builtin();
    let cfg = tiny_cfg(Algorithm::DpSgd);
    let gcfg = gen_cfg(&rt, &cfg);

    let gen = SynthCriteo::new(gcfg.clone());
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let scalar = trainer.run_pctr(&gen).unwrap();
    assert_eq!(scalar.telemetry.kernel_backend, "scalar");
    drop(trainer);

    let mut c = cfg.clone();
    c.engine.kernel_backend = KernelBackend::Simd;
    let simd = engine::run_pctr(&c, &rt, gcfg).unwrap();
    assert_eq!(simd.telemetry.kernel_backend, "simd");
    assert_trajectories_close(&scalar.loss_history, &simd.loss_history, "criteo-tiny dp-sgd");
}

#[test]
fn simd_loss_trajectory_tracks_scalar_on_nlu_lora() {
    let _guard = config_lock();
    let rt = Runtime::builtin();
    let mut cfg = tiny_nlu_cfg(Algorithm::DpSgd);
    cfg.model = "nlu-tiny-lora4".into();
    let tcfg = text_cfg(&rt, &cfg);

    let gen = SynthText::new(tcfg.clone());
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let scalar = trainer.run_text(&gen).unwrap();
    assert_eq!(scalar.telemetry.kernel_backend, "scalar");
    drop(trainer);

    let mut c = cfg.clone();
    c.engine.kernel_backend = KernelBackend::Simd;
    let simd = engine::run_text(&c, &rt, tcfg).unwrap();
    assert_eq!(simd.telemetry.kernel_backend, "simd");
    assert_trajectories_close(&scalar.loss_history, &simd.loss_history, "nlu-tiny-lora4 dp-sgd");
}

#[test]
fn simd_sync_and_async_match_exactly() {
    // both sides on the SIMD backend: the engine's determinism guarantees
    // are backend-independent, so sync vs async stays bit-for-bit —
    // outcomes AND final parameters — even with threaded kernels
    let _guard = config_lock();
    let rt = Runtime::builtin();
    for model in ["criteo-tiny", "nlu-tiny-lora4"] {
        let mut cfg = if model == "criteo-tiny" {
            tiny_cfg(Algorithm::DpAdaFest)
        } else {
            tiny_nlu_cfg(Algorithm::DpAdaFest)
        };
        cfg.model = model.into();
        cfg.engine.kernel_backend = KernelBackend::Simd;

        let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
        let sync_out = match model {
            "criteo-tiny" => {
                let gen = SynthCriteo::new(gen_cfg(&rt, &cfg));
                trainer.run_pctr(&gen).unwrap()
            }
            _ => {
                let gen = SynthText::new(text_cfg(&rt, &cfg));
                trainer.run_text(&gen).unwrap()
            }
        };
        assert!(sync_out.loss_history.iter().all(|l| l.is_finite()), "{model}");
        assert_eq!(sync_out.telemetry.kernel_backend, "simd", "{model}");

        let mut c = cfg.clone();
        c.engine.grad_workers = 3;
        c.engine.shards = 4;
        c.engine.kernel_threads = 2;
        let (async_out, async_store) = engine::run_with_params(&c, &rt).unwrap();
        let what = format!("{model} simd sync-vs-async");
        assert_outcomes_identical(&sync_out, &async_out, &what);
        assert_params_identical(&trainer.store, &async_store, &what);
        assert_eq!(async_out.telemetry.kernel_backend, "simd", "{model}");
    }
}

#[test]
fn simd_multi_process_matches_in_process() {
    // the actor fleet ships `kernel_backend` in `GradInit`, so a 2-process
    // SIMD run must be bit-identical to the in-process SIMD engine
    let _guard = config_lock();
    use_cli_actor_exe();
    let rt = Runtime::builtin();
    let mut cfg = tiny_cfg(Algorithm::DpAdaFest);
    cfg.engine.kernel_backend = KernelBackend::Simd;
    cfg.engine.grad_workers = 2;
    cfg.engine.shards = 4;
    let (in_proc, in_store) = engine::run_with_params(&cfg, &rt).unwrap();

    let mut c = cfg.clone();
    c.engine.processes = 2;
    let (multi, multi_store) = engine::run_with_params(&c, &rt).unwrap();
    assert_outcomes_identical(&in_proc, &multi, "simd 2-process");
    assert_params_identical(&in_store, &multi_store, "simd 2-process");
    assert_eq!(multi.telemetry.kernel_backend, "simd");
}

#[test]
fn kernel_knobs_restore_after_each_run() {
    // The bugfix regression: runs used to *leak* their kernel knobs into
    // the process globals (set at run start, never restored).  With the
    // scoped guard, a threaded SIMD run must leave the globals exactly
    // where it found them — and a follow-up default run must see (and
    // report) the scalar defaults.
    let _guard = config_lock();
    let rt = Runtime::builtin();
    assert_eq!(kernels::threads(), 1, "precondition: default thread count");
    assert_eq!(kernels::backend(), KernelBackend::Scalar, "precondition: default backend");

    let mut cfg = tiny_cfg(Algorithm::DpSgd);
    cfg.steps = 2;
    cfg.engine.kernel_threads = 3;
    cfg.engine.kernel_backend = KernelBackend::Simd;
    let gcfg = gen_cfg(&rt, &cfg);
    let out = engine::run_pctr(&cfg, &rt, gcfg.clone()).unwrap();
    assert_eq!(out.telemetry.kernel_backend, "simd");
    assert_eq!(kernels::threads(), 1, "engine run leaked kernel_threads");
    assert_eq!(kernels::backend(), KernelBackend::Scalar, "engine run leaked kernel_backend");

    // same process, same knobs, sync path
    let mut c = cfg.clone();
    c.engine.kernel_threads = 2;
    let gen = SynthCriteo::new(gcfg.clone());
    let mut trainer = Trainer::new(c, &rt).unwrap();
    trainer.run_pctr(&gen).unwrap();
    drop(trainer);
    assert_eq!(kernels::threads(), 1, "sync trainer leaked kernel_threads");
    assert_eq!(kernels::backend(), KernelBackend::Scalar, "sync trainer leaked kernel_backend");

    // a default run in the same process reports the scalar backend
    let dcfg = tiny_cfg(Algorithm::DpSgd);
    let out = engine::run_pctr(&dcfg, &rt, gen_cfg(&rt, &dcfg)).unwrap();
    assert_eq!(out.telemetry.kernel_backend, "scalar");
}
