//! Property suite for the embedding-table store backends: the file-backed
//! [`PagedTable`] against the in-RAM [`ShardedTable`] oracle (and a flat
//! single-slice application), byte for byte.
//!
//! The bit-exactness claim (docs/ENGINE.md): both backends run the same
//! per-coordinate optimizer code on sub-ranges of the table, so any
//! partitioning — shards or pages — produces identical values AND identical
//! Adagrad accumulator state.  Checked here over random row patterns, page
//! sizes, shard counts, and cache budgets under the in-repo property
//! harness, plus deterministic edge cases the issue calls out: a budget of
//! a single page, vocab not a multiple of the page size, repeated rows in
//! one scatter, eviction-then-reread of a dirty page, and crash-consistency
//! of the page-file header ([`PagedTable::check_clean`]).

use std::path::PathBuf;
use std::sync::Arc;

use sparse_dp_emb::proptest::{check, ensure, usize_in};
use sparse_dp_emb::sparse::{DenseState, Optimizer, RowSparseGrad};
use sparse_dp_emb::store::{default_page_rows, unique_path, PagedTable, ShardedTable};
use sparse_dp_emb::telemetry::Telemetry;
use sparse_dp_emb::util::rng::Xoshiro256;

fn tmp(label: &str) -> PathBuf {
    unique_path(&std::env::temp_dir(), label)
}

#[test]
fn prop_paged_matches_sharded_oracle_bitwise() {
    // random tables, random scatters (repeated rows allowed), interleaved
    // row reads, then final (values, accum) — all three representations
    // must agree exactly
    check("paged == sharded == flat", 60, |rng| {
        let rows = usize_in(rng, 1, 300);
        let dim = usize_in(rng, 1, 8);
        let page_rows = usize_in(rng, 1, rows + 3); // clamped to rows inside
        let shards = usize_in(rng, 1, 9);
        let page_cost = page_rows.min(rows) * dim * 8;
        let budget = page_cost * usize_in(rng, 1, 4); // 1..4 resident pages
        let opt = if rng.uniform() < 0.5 {
            Optimizer::adagrad(0.05)
        } else {
            Optimizer::sgd(0.05)
        };
        let init: Vec<f32> = (0..rows * dim).map(|_| rng.gauss() as f32).collect();

        let mut flat = init.clone();
        let mut flat_state = DenseState::default();
        let sharded = ShardedTable::from_dense(rows, dim, init.clone(), shards);
        let paged =
            PagedTable::from_dense(tmp("prop"), rows, dim, init, page_rows, budget)
                .map_err(|e| e.to_string())?;

        for _ in 0..usize_in(rng, 1, 6) {
            let mut g = RowSparseGrad::new(rows, dim);
            for _ in 0..usize_in(rng, 0, 40) {
                let r = rng.below(rows as u64) as u32;
                let vals: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
                g.add_row(r, &vals);
            }
            opt.sparse_step(&mut flat, &g, &mut flat_state);
            sharded.apply_sparse(&g, &opt);
            paged.apply_sparse(&g, &opt).map_err(|e| e.to_string())?;

            let (mut a, mut b) = (vec![0f32; dim], vec![0f32; dim]);
            for _ in 0..5 {
                let r = rng.below(rows as u64) as usize;
                sharded.read_row(r, &mut a);
                paged.read_row(r, &mut b).map_err(|e| e.to_string())?;
                ensure(a == b, format!("row {r} read diverged mid-run"))?;
                ensure(
                    b == flat[r * dim..(r + 1) * dim],
                    format!("row {r} diverged from flat"),
                )?;
            }
        }
        ensure(
            paged.resident_pages() <= paged.budget_pages(),
            "resident pages exceeded the budget",
        )?;
        let (sv, sa) = sharded.into_dense();
        let (pv, pa) = paged.into_dense().map_err(|e| e.to_string())?;
        ensure(sv == flat && pv == flat, "final values diverged")?;
        ensure(sa == pa, "final accumulator diverged")
    });
}

#[test]
fn one_page_budget_evicts_and_rereads_dirty_pages() {
    // budget = exactly one page, vocab not a multiple of the page size
    // (7 rows, 3-row pages → last page short): touching a second page must
    // write the first (dirty) page back, and re-reading it must see the
    // scattered values, not the initial ones
    let (rows, dim, page_rows) = (7usize, 3usize, 3usize);
    let init: Vec<f32> = (0..rows * dim).map(|i| i as f32 * 0.5).collect();
    let opt = Optimizer::adagrad(0.1);
    let mut flat = init.clone();
    let mut flat_state = DenseState::default();

    let paged = PagedTable::from_dense(
        tmp("onepage"),
        rows,
        dim,
        init.clone(),
        page_rows,
        page_rows * dim * 8,
    )
    .unwrap();
    assert_eq!(paged.budget_pages(), 1);

    let mut g = RowSparseGrad::new(rows, dim);
    g.add_row(0, &[1.0, 2.0, 3.0]);
    g.add_row(1, &[-0.5, 0.25, 4.0]);
    opt.sparse_step(&mut flat, &g, &mut flat_state);
    paged.apply_sparse(&g, &opt).unwrap();
    assert_eq!(paged.resident_pages(), 1);

    // touch the short last page: evicts dirty page 0
    let mut out = vec![0f32; dim];
    paged.read_row(rows - 1, &mut out).unwrap();
    assert_eq!(out, init[(rows - 1) * dim..]);
    assert_eq!(paged.resident_pages(), 1);

    // re-read the written-back page
    paged.read_row(0, &mut out).unwrap();
    assert_eq!(out, flat[0..dim]);

    let (values, accum) = paged.into_dense().unwrap();
    assert_eq!(values, flat);
    assert_eq!(accum, flat_state.accum().to_vec());
}

#[test]
fn repeated_rows_in_one_scatter_match_flat() {
    // RowSparseGrad pre-accumulates a repeated row id into one entry, so
    // the paged apply must see the same summed row as the flat oracle —
    // with the repeats spanning several pages of a multi-page table
    let (rows, dim, page_rows) = (6usize, 2usize, 2usize);
    let init = vec![0.25f32; rows * dim];
    let opt = Optimizer::adagrad(0.2);
    let mut flat = init.clone();
    let mut flat_state = DenseState::default();

    let paged =
        PagedTable::from_dense(tmp("repeat"), rows, dim, init, page_rows, page_rows * dim * 8)
            .unwrap();
    let mut g = RowSparseGrad::new(rows, dim);
    g.add_row(3, &[1.0, -1.0]);
    g.add_row(0, &[0.5, 0.5]);
    g.add_row(3, &[2.0, 0.25]); // same row again, later in the sequence
    g.add_row(5, &[-0.125, 8.0]);
    opt.sparse_step(&mut flat, &g, &mut flat_state);
    paged.apply_sparse(&g, &opt).unwrap();

    let (values, accum) = paged.into_dense().unwrap();
    assert_eq!(values, flat);
    assert_eq!(accum, flat_state.accum().to_vec());
}

#[test]
fn dense_apply_matches_flat_across_pages() {
    // the DP-SGD embedding baseline walks every page in row order
    let (rows, dim, page_rows) = (11usize, 3usize, 4usize);
    let init: Vec<f32> = (0..rows * dim).map(|i| (i as f32).sin()).collect();
    let grad: Vec<f32> = (0..rows * dim).map(|i| (i % 5) as f32 * 0.1 - 0.2).collect();
    for opt in [Optimizer::sgd(0.3), Optimizer::adagrad(0.3)] {
        let mut flat = init.clone();
        let mut flat_state = DenseState::default();
        opt.dense_step(&mut flat, &grad, &mut flat_state);
        let paged = PagedTable::from_dense(
            tmp("dense"),
            rows,
            dim,
            init.clone(),
            page_rows,
            page_rows * dim * 8, // one page resident at a time
        )
        .unwrap();
        paged.apply_dense(&grad, &opt).unwrap();
        let (values, accum) = paged.into_dense().unwrap();
        assert_eq!(values, flat);
        assert_eq!(accum, flat_state.accum().to_vec());
    }
}

#[test]
fn create_zeroed_serves_zeros_within_budget_and_cleans_up() {
    // a zero-initialised table never materialises rows × dim anywhere: the
    // file is a sparse hole and unwritten pages read back as zeros
    let (rows, dim) = (1_000_000usize, 4usize);
    let page_rows = default_page_rows(dim);
    let budget = 2 * page_rows * dim * 8;
    let path = tmp("zeroed");
    let paged =
        PagedTable::create_zeroed(path.clone(), rows, dim, page_rows, budget).unwrap();
    assert_eq!(paged.budget_pages(), 2);

    let mut rng = Xoshiro256::seed_from(11);
    let mut out = vec![1f32; dim];
    for _ in 0..50 {
        paged.read_row(rng.below(rows as u64) as usize, &mut out).unwrap();
        assert_eq!(out, vec![0f32; dim]);
        assert!(paged.resident_pages() <= 2);
    }
    let mut g = RowSparseGrad::new(rows, dim);
    g.add_row(999_999, &[1.0, 2.0, 3.0, 4.0]);
    paged.apply_sparse(&g, &Optimizer::sgd(1.0)).unwrap();
    paged.read_row(999_999, &mut out).unwrap();
    assert_eq!(out, [-1.0, -2.0, -3.0, -4.0]);

    // a plain drop (error path) removes the page file too
    assert!(path.exists());
    drop(paged);
    assert!(!path.exists());
}

#[test]
fn telemetry_gauge_tracks_resident_bytes_and_respects_budget() {
    let tele = Arc::new(Telemetry::new());
    let (rows, dim, page_rows) = (100usize, 4usize, 8usize);
    let page_cost = page_rows * dim * 8;
    let paged = PagedTable::create_zeroed(tmp("gauge"), rows, dim, page_rows, 2 * page_cost)
        .unwrap()
        .with_telemetry(Arc::clone(&tele));

    let mut out = vec![0f32; dim];
    for r in (0..rows).step_by(7) {
        paged.read_row(r, &mut out).unwrap();
        assert_eq!(tele.store_resident(), paged.resident_bytes());
    }
    // Adagrad materialises accumulators on resident pages — gauge grows but
    // the high-water stays within the worst-case budget (values + accum)
    let mut g = RowSparseGrad::new(rows, dim);
    for r in [0u32, 13, 77, 99] {
        g.add_row(r, &[0.1, 0.2, 0.3, 0.4]);
    }
    paged.apply_sparse(&g, &Optimizer::adagrad(0.1)).unwrap();
    assert_eq!(tele.store_resident(), paged.resident_bytes());
    assert!(tele.store_resident_max() <= (2 * page_cost) as u64);

    paged.into_dense().unwrap();
    assert_eq!(tele.store_resident(), 0, "teardown must release the gauge");
}

#[test]
fn check_clean_rejects_crashed_and_foreign_files() {
    // simulate a process dying mid-run: the table is neither finalised nor
    // dropped, so the page file keeps its open-state header on disk
    let path = tmp("crash");
    let t = PagedTable::from_dense(path.clone(), 4, 2, vec![0.1; 8], 2, 1024).unwrap();
    let mut g = RowSparseGrad::new(4, 2);
    g.add_row(1, &[1.0, -1.0]);
    t.apply_sparse(&g, &Optimizer::sgd(0.5)).unwrap();
    std::mem::forget(t);
    let err = PagedTable::check_clean(&path).unwrap_err();
    assert!(
        err.to_string().contains("not cleanly closed"),
        "wrong rejection: {err:#}"
    );
    std::fs::remove_file(&path).unwrap();

    // junk that is not a page file at all
    let junk = tmp("junk");
    std::fs::write(&junk, [0u8; 64]).unwrap();
    assert!(PagedTable::check_clean(&junk).is_err());
    std::fs::remove_file(&junk).unwrap();

    // a cleanly finalised table leaves nothing behind to check
    let done = tmp("done");
    let t = PagedTable::from_dense(done.clone(), 4, 2, vec![0.1; 8], 2, 1024).unwrap();
    t.into_dense().unwrap();
    assert!(!done.exists());
}
