//! Helpers shared by the engine-facing integration suites
//! (`tests/engine.rs`, `tests/telemetry.rs`, `tests/engine_fault.rs`): the
//! tiny run configs, the manifest-derived generator configs, the
//! outcome/parameter bit-exactness assertions every sync-vs-async
//! comparison uses, and the multi-process plumbing (CLI actor binary,
//! hang watchdog).  Each test binary compiles its own copy and uses a
//! subset, hence the `dead_code` allowance.
#![allow(dead_code)]

pub mod ulp;

use std::path::PathBuf;
use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use sparse_dp_emb::config::RunConfig;
use sparse_dp_emb::coordinator::{
    Algorithm, StreamingOutcome, StreamingTrainer, TrainOutcome, Trainer,
};
use sparse_dp_emb::data::{CriteoConfig, SynthCriteo, TextConfig};
use sparse_dp_emb::models::ParamStore;
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::selection::FrequencySource;

/// Six steps of the tiny pCTR tower — the cheapest end-to-end DP run.
pub fn tiny_cfg(algo: Algorithm) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-tiny".into();
    cfg.algorithm = algo;
    cfg.steps = 6;
    cfg.eval_batches = 2;
    cfg.c2 = 0.5;
    cfg
}

/// Four steps of the tiny NLU transformer.
pub fn tiny_nlu_cfg(algo: Algorithm) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "nlu-tiny".into();
    cfg.algorithm = algo;
    cfg.steps = 4;
    cfg.eval_batches = 2;
    cfg.c2 = 0.5;
    cfg.tau = 2.0;
    cfg
}

/// The §4.3 streaming protocol config: one step per training day.
pub fn streaming_cfg(algo: Algorithm, source: FrequencySource, period: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "criteo-tiny".into();
    cfg.algorithm = algo;
    cfg.steps = 18; // 1 step/day over the 18 training days
    cfg.eval_batches = 4;
    cfg.c2 = 0.5;
    cfg.fest_top_k = 64;
    cfg.freq_source = source;
    cfg.streaming_period = period;
    cfg
}

/// The pCTR generator config the CLI would derive for `cfg.model`.
pub fn gen_cfg(rt: &Runtime, cfg: &RunConfig) -> CriteoConfig {
    let model = rt.manifest.model(&cfg.model).unwrap();
    let vocabs = model.attr_usize_list("vocabs").unwrap();
    CriteoConfig::new(vocabs, cfg.seed ^ 0xDA7A)
}

/// The text generator config the CLI would derive for `cfg.model`.
pub fn text_cfg(rt: &Runtime, cfg: &RunConfig) -> TextConfig {
    let model = rt.manifest.model(&cfg.model).unwrap();
    TextConfig::from_model(model, cfg.seed ^ 0xDA7A).unwrap()
}

/// Run the synchronous `StreamingTrainer` reference for a streaming config.
pub fn sync_streaming(cfg: &RunConfig, rt: &Runtime, gcfg: &CriteoConfig) -> StreamingOutcome {
    let gen = SynthCriteo::new(gcfg.clone());
    let trainer = Trainer::new(cfg.clone(), rt).unwrap();
    let mut st = StreamingTrainer::new(trainer, 2);
    st.run(&gen).unwrap()
}

/// The bit-exactness bar on outcomes: every paper-semantic field equal.
pub fn assert_outcomes_identical(a: &TrainOutcome, b: &TrainOutcome, what: &str) {
    assert_eq!(a.loss_history, b.loss_history, "{what}: loss history");
    assert_eq!(a.utility, b.utility, "{what}: utility");
    assert_eq!(a.eval_loss, b.eval_loss, "{what}: eval loss");
    assert_eq!(
        a.emb_grad_coords_per_step, b.emb_grad_coords_per_step,
        "{what}: emb coords/step"
    );
    assert_eq!(a.sigma1, b.sigma1, "{what}: sigma1");
    assert_eq!(a.sigma2, b.sigma2, "{what}: sigma2");
}

/// The bit-exactness bar on final parameters: same names, same f32 bits,
/// coordinate for coordinate.
pub fn assert_params_identical(a: &ParamStore, b: &ParamStore, what: &str) {
    assert_eq!(a.params.len(), b.params.len(), "{what}: param count");
    for (pa, pb) in a.params.iter().zip(&b.params) {
        assert_eq!(pa.name, pb.name, "{what}: param order");
        assert_eq!(
            pa.tensor.as_f32().unwrap(),
            pb.tensor.as_f32().unwrap(),
            "{what}: param {} diverged",
            pa.name
        );
    }
}

/// Streaming-mode equality: the outcome, the per-day AUCs, and the DP-FEST
/// reselection count.
pub fn assert_streaming_identical(a: &StreamingOutcome, b: &StreamingOutcome, what: &str) {
    assert_outcomes_identical(&a.outcome, &b.outcome, what);
    assert_eq!(a.per_day_auc, b.per_day_auc, "{what}: per-day AUC");
    assert_eq!(a.reselections, b.reselections, "{what}: reselections");
}

/// Route multi-process actor children through the CLI binary.
///
/// The test executable's `main` is the libtest harness, which never reaches
/// `engine::actor::maybe_actor_main` — so spawning *ourselves* as an actor
/// would rerun the test suite instead.  Every test that sets
/// `engine.processes >= 2` must call this first.
pub fn use_cli_actor_exe() {
    sparse_dp_emb::engine::actor::set_actor_exe(PathBuf::from(env!(
        "CARGO_BIN_EXE_sparse-dp-emb"
    )));
}

/// Hard watchdog for shutdown/no-deadlock tests: run `f` on a helper
/// thread and panic if it has not finished within `secs` — a bounded-time
/// failure instead of a hung test binary.  A panic inside `f` is
/// propagated unchanged.
pub fn watchdog<T, F>(secs: u64, what: &str, f: F) -> T
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::Builder::new()
        .name(format!("watchdog:{what}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .unwrap();
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => {
            let _ = handle.join();
            v
        }
        // the sender dropped without sending: `f` panicked — re-raise it
        Err(RecvTimeoutError::Disconnected) => match handle.join() {
            Err(panic) => std::panic::resume_unwind(panic),
            Ok(()) => unreachable!("worker exited without sending or panicking"),
        },
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: still running after the {secs}s watchdog — deadlock or orphaned wait")
        }
    }
}
