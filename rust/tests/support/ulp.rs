//! ULP-distance comparison for f32 numerics suites (satellite of the SIMD
//! backend PR, reused by `tests/simd.rs` and `tests/kernels.rs`).
//!
//! "ULP distance" is the number of representable f32 values strictly
//! between two floats, plus one — i.e. how many times you would have to
//! call `nextafter` to walk from one to the other.  It is the right
//! yardstick for "same computation, reassociated": a handful of ULPs is
//! rounding noise, a large gap is a real numeric divergence, and the
//! metric is scale-free (no tuning an absolute epsilon per magnitude).
//!
//! The implementation uses the classic monotone bit map: reinterpret the
//! IEEE 754 bits so that the total order on the mapped integers matches
//! the numeric order on floats.  For non-negative floats the bit pattern
//! is already monotone; negative floats order in reverse, so they map to
//! the negated magnitude.  Consequences worth pinning (and tested below):
//!
//! * `+0.0` and `-0.0` both map to 0 — ULP distance 0, as it should be
//!   (they compare numerically equal).
//! * The distance crosses zero smoothly: the two signed subnormals
//!   nearest zero are 2 ULPs apart (one step to ±0, one step across).
//! * `f32::MAX` and `+inf` are adjacent (distance 1): an overflowing lane
//!   sum shows up as a bounded-ULP failure, not a weird huge number.
//! * NaN has no place on the number line: if exactly one side is NaN the
//!   distance is `None` ("unboundedly far"); if both are NaN we report
//!   `Some(0)` so a kernel that legitimately propagates NaN for NaN input
//!   still compares equal to the scalar oracle doing the same.

/// Map f32 bits onto integers such that numeric order ⇒ integer order.
/// Both zeros map to 0.  Must only be called on non-NaN values.
fn monotone(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7FFF_FFFF) as i64)
    } else {
        b as i64
    }
}

/// ULP distance between two floats, or `None` if exactly one is NaN.
pub fn ulp_distance(a: f32, b: f32) -> Option<u64> {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Some(0),
        (true, false) | (false, true) => None,
        (false, false) => Some(monotone(a).abs_diff(monotone(b))),
    }
}

/// `Ok(())` if `got` is within `max_ulp` ULPs of `want`, else a message
/// with the values, their bits, and the observed distance.
pub fn close_ulp(max_ulp: u64, got: f32, want: f32) -> Result<(), String> {
    match ulp_distance(got, want) {
        Some(d) if d <= max_ulp => Ok(()),
        Some(d) => Err(format!(
            "{got:e} (bits {:#010x}) vs {want:e} (bits {:#010x}): {d} ULPs apart (max {max_ulp})",
            got.to_bits(),
            want.to_bits()
        )),
        None => Err(format!(
            "{got:e} vs {want:e}: exactly one side is NaN (unbounded ULP distance)"
        )),
    }
}

/// Assert two slices are elementwise within `max_ulp` ULPs.
pub fn assert_close_ulp(max_ulp: u64, got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        if let Err(msg) = close_ulp(max_ulp, g, w) {
            panic!("{what}: element {i}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacent_floats_are_one_ulp_apart() {
        let a = 1.0f32;
        let b = f32::from_bits(a.to_bits() + 1);
        assert_eq!(ulp_distance(a, b), Some(1));
        assert_eq!(ulp_distance(b, a), Some(1));
        assert_eq!(ulp_distance(a, a), Some(0));
        // same neighbour relation holds on the negative side
        let c = -1.0f32;
        let d = f32::from_bits(c.to_bits() + 1); // more negative magnitude
        assert_eq!(ulp_distance(c, d), Some(1));
    }

    #[test]
    fn signed_zeros_are_zero_apart() {
        assert_eq!(ulp_distance(0.0, -0.0), Some(0));
        assert_eq!(ulp_distance(-0.0, 0.0), Some(0));
        assert!(close_ulp(0, 0.0, -0.0).is_ok());
    }

    #[test]
    fn distance_crosses_zero_through_the_subnormals() {
        let tiny = f32::from_bits(1); // smallest positive subnormal
        assert_eq!(ulp_distance(tiny, 0.0), Some(1));
        assert_eq!(ulp_distance(tiny, -tiny), Some(2));
        assert_eq!(ulp_distance(-tiny, 0.0), Some(1));
    }

    #[test]
    fn nan_semantics() {
        assert_eq!(ulp_distance(f32::NAN, f32::NAN), Some(0));
        assert_eq!(ulp_distance(f32::NAN, 1.0), None);
        assert_eq!(ulp_distance(1.0, f32::NAN), None);
        assert!(close_ulp(u64::MAX, f32::NAN, 1.0).is_err());
        assert!(close_ulp(0, f32::NAN, f32::NAN).is_ok());
    }

    #[test]
    fn infinity_is_adjacent_to_max() {
        assert_eq!(ulp_distance(f32::MAX, f32::INFINITY), Some(1));
        assert_eq!(ulp_distance(f32::MIN, f32::NEG_INFINITY), Some(1));
    }

    #[test]
    fn slice_helper_accepts_within_bound() {
        let want = [1.0f32, -2.0, 0.0, 3.5e-3];
        assert_close_ulp(0, &want, &want, "identical");
        let nudge = |w: f32| if w == 0.0 { -0.0 } else { f32::from_bits(w.to_bits() + 2) };
        let nudged: Vec<f32> = want.iter().map(|&w| nudge(w)).collect();
        assert_close_ulp(2, &nudged, &want, "2-ulp nudge");
    }

    #[test]
    #[should_panic(expected = "ULPs apart")]
    fn slice_helper_rejects_beyond_bound() {
        let want = [1.0f32];
        let got = [f32::from_bits(want[0].to_bits() + 8)];
        assert_close_ulp(4, &got, &want, "too far");
    }
}
