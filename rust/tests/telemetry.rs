//! Telemetry acceptance tests:
//!
//! * instrumentation is **passive** — the sync==async bit-exactness bar
//!   (outcomes AND final parameters) holds with a live `--metrics-out` sink
//!   on both workloads, and two traces agree row-for-row on every
//!   paper-semantic gauge;
//! * counters are **consistent** — under plain DP-SGD the per-step noised
//!   coordinate count equals the analytic dense `V·d` baseline (reduction
//!   factor exactly 1), span counts match the step/chunk arithmetic, and
//!   the summary's step count equals the configured run length;
//! * both hold **across the socket boundary** — in multi-process mode the
//!   actors' stage timers ride `DataDone`/`FinalizeResult` frames and
//!   merge into the barrier hub (`Telemetry::merge_stage_totals`), so the
//!   same step/chunk arithmetic and paper gauges come out;
//! * the checked-in `BENCH_engine.json` parses under the current schema.

mod support;

use support::{
    assert_outcomes_identical, assert_params_identical, gen_cfg, text_cfg, tiny_cfg, tiny_nlu_cfg,
};

use sparse_dp_emb::coordinator::{Algorithm, Trainer};
use sparse_dp_emb::data::{SynthCriteo, SynthText};
use sparse_dp_emb::engine;
use sparse_dp_emb::runtime::Runtime;
use sparse_dp_emb::telemetry::json::Json;
use sparse_dp_emb::telemetry::{BenchSnapshot, Stage, Telemetry, BENCH_SCHEMA_VERSION};

/// A per-test temp sink path (runs share a process; paths must not collide).
fn sink_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("telemetry_it_{}_{tag}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn read_jsonl(path: &str) -> Vec<Json> {
    let text = std::fs::read_to_string(path).unwrap();
    std::fs::remove_file(path).ok();
    text.lines().map(|l| Json::parse(l).unwrap()).collect()
}

/// The paper-semantic step fields two traces of the same run must agree on.
/// Stage timings and queue depths are deliberately excluded — those describe
/// the execution, not the training trajectory.
const PAPER_KEYS: &[&str] = &[
    "step",
    "loss",
    "present_rows",
    "survivors",
    "emb_coords_noised",
    "dense_coords_noised",
    "reduction_factor",
    "eps_spent",
    "delta",
];

fn assert_paper_rows_identical(a: &[Json], b: &[Json], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: line count");
    for (i, (la, lb)) in a.iter().zip(b).enumerate() {
        assert_eq!(la.get("type"), lb.get("type"), "{what}: line {i} type");
        if la.get("type").and_then(Json::as_str) != Some("step") {
            continue;
        }
        for key in PAPER_KEYS {
            assert_eq!(la.get(key), lb.get(key), "{what}: line {i} field `{key}`");
        }
    }
}

#[test]
fn sync_and_async_pctr_match_exactly_with_live_sink() {
    // The passive-instrumentation acceptance bar: telemetry (with a live
    // JSONL sink on both paths) perturbs nothing — outcomes, final
    // parameters, and the paper gauges in the traces are all bit-identical
    // sync vs async.
    let rt = Runtime::builtin();
    for algo in [Algorithm::DpSgd, Algorithm::DpAdaFest] {
        let sync_path = sink_path(&format!("pctr_sync_{algo:?}"));
        let async_path = sink_path(&format!("pctr_async_{algo:?}"));

        let mut cfg = tiny_cfg(algo);
        cfg.metrics_out = sync_path.clone();
        let gcfg = gen_cfg(&rt, &cfg);
        let gen = SynthCriteo::new(gcfg);
        let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
        let sync_out = trainer.run_pctr(&gen).unwrap();

        let mut acfg = cfg.clone();
        acfg.metrics_out = async_path.clone();
        acfg.engine.grad_workers = 3;
        acfg.engine.data_workers = 2;
        acfg.engine.shards = 7;
        let (async_out, async_store) = engine::run_with_params(&acfg, &rt).unwrap();

        let what = format!("pctr {algo:?} with sink");
        assert_outcomes_identical(&sync_out, &async_out, &what);
        assert_params_identical(&trainer.store, &async_store, &what);

        let sync_lines = read_jsonl(&sync_path);
        let async_lines = read_jsonl(&async_path);
        // one line per step plus the final summary
        assert_eq!(sync_lines.len(), cfg.steps as usize + 1, "{what}");
        assert_eq!(
            sync_lines.last().unwrap().get("type").and_then(Json::as_str),
            Some("summary"),
            "{what}"
        );
        assert_paper_rows_identical(&sync_lines, &async_lines, &what);
        // both paths run bit-exact here, so every step line reports a
        // zero snapshot age (the field only rises at --engine-staleness > 0)
        for line in sync_lines.iter().chain(&async_lines) {
            if line.get("type").and_then(Json::as_str) == Some("step") {
                assert_eq!(
                    line.get("staleness").and_then(Json::as_u64),
                    Some(0),
                    "{what}: staleness field"
                );
            }
        }
    }
}

#[test]
fn sync_and_async_nlu_match_exactly_with_live_sink() {
    let rt = Runtime::builtin();
    let sync_path = sink_path("nlu_sync");
    let async_path = sink_path("nlu_async");

    let mut cfg = tiny_nlu_cfg(Algorithm::DpAdaFest);
    cfg.metrics_out = sync_path.clone();
    let gen = SynthText::new(text_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let sync_out = trainer.run_text(&gen).unwrap();

    let mut acfg = cfg.clone();
    acfg.metrics_out = async_path.clone();
    acfg.engine.grad_workers = 2;
    acfg.engine.shards = 4;
    let (async_out, async_store) = engine::run_with_params(&acfg, &rt).unwrap();

    assert_outcomes_identical(&sync_out, &async_out, "nlu with sink");
    assert_params_identical(&trainer.store, &async_store, "nlu with sink");
    assert_paper_rows_identical(
        &read_jsonl(&sync_path),
        &read_jsonl(&async_path),
        "nlu with sink",
    );
}

#[test]
fn dp_sgd_counters_match_the_analytic_dense_baseline() {
    // Under plain DP-SGD every embedding coordinate is noised every step, so
    // the trace's per-step count must equal the analytic V·d total and the
    // per-step reduction factor must be exactly 1.
    let rt = Runtime::builtin();
    let path = sink_path("dense_baseline");
    let mut cfg = tiny_cfg(Algorithm::DpSgd);
    cfg.metrics_out = path.clone();
    let gen = SynthCriteo::new(gen_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let vd_total: u64 = trainer
        .emb_tables()
        .iter()
        .map(|t| (t.vocab * t.dim) as u64)
        .sum();
    trainer.run_pctr(&gen).unwrap();

    let lines = read_jsonl(&path);
    let mut last_eps = 0.0;
    for line in &lines {
        if line.get("type").and_then(Json::as_str) != Some("step") {
            continue;
        }
        assert_eq!(
            line.get("emb_coords_noised").and_then(Json::as_u64),
            Some(vd_total),
            "emb_coords_noised must equal the dense V·d total"
        );
        assert_eq!(
            line.get("reduction_factor").and_then(Json::as_f64),
            Some(1.0),
            "dense DP-SGD has no gradient-size reduction"
        );
        // no selection stage under DP-SGD
        assert_eq!(line.get("survivors"), Some(&Json::Null));
        // cumulative privacy spend never decreases
        let eps = line.get("eps_spent").and_then(Json::as_f64).unwrap();
        assert!(eps >= last_eps, "eps_spent decreased: {eps} < {last_eps}");
        assert!(eps.is_finite() && eps > 0.0);
        last_eps = eps;
        assert_eq!(
            line.get("delta").and_then(Json::as_f64),
            Some(cfg.effective_delta())
        );
    }
}

#[test]
fn span_and_gauge_totals_match_step_arithmetic() {
    let rt = Runtime::builtin();
    let cfg = tiny_cfg(Algorithm::DpAdaFest);

    // sync: one artifact execution per step, no channels
    let gen = SynthCriteo::new(gen_cfg(&rt, &cfg));
    let mut trainer = Trainer::new(cfg.clone(), &rt).unwrap();
    let batch = trainer.batch_size();
    let sync = trainer.run_pctr(&gen).unwrap().telemetry;
    assert_eq!(sync.steps, cfg.steps);
    assert_eq!(sync.stage(Stage::ChunkCompute).unwrap().count, cfg.steps);
    assert_eq!(sync.stage(Stage::Select).unwrap().count, cfg.steps);
    assert_eq!(sync.stage(Stage::DataGenerate).unwrap().count, cfg.steps);
    assert_eq!(sync.batch_queue_max, 0, "sync path has no batch channel");
    assert_eq!(sync.task_queue_max, 0, "sync path has no task channel");
    assert!(sync.wall_secs > 0.0);

    // async: one chunk computation per 16-example reduction chunk, and the
    // pipeline channels must have actually carried messages
    let mut acfg = cfg.clone();
    acfg.engine.grad_workers = 3;
    acfg.engine.data_workers = 2;
    let run = engine::run_pctr(&acfg, &rt, gen_cfg(&rt, &acfg)).unwrap();
    let tele = &run.telemetry;
    assert_eq!(tele.steps, cfg.steps);
    let chunks_per_step = batch.div_ceil(16) as u64;
    assert_eq!(
        tele.stage(Stage::ChunkCompute).unwrap().count,
        cfg.steps * chunks_per_step,
        "one chunk computation per reduction chunk"
    );
    assert_eq!(tele.stage(Stage::Select).unwrap().count, cfg.steps);
    assert_eq!(tele.stage(Stage::Snapshot).unwrap().count, cfg.steps);
    assert_eq!(tele.stage(Stage::Collect).unwrap().count, cfg.steps);
    assert_eq!(tele.stage(Stage::DataGenerate).unwrap().count, cfg.steps);
    assert!(tele.batch_queue_max >= 1, "batch channel never carried a message");
    assert!(tele.task_queue_max >= 1, "task channel never carried a message");
}

#[test]
fn multi_process_stage_totals_cross_the_socket_boundary() {
    // The same step/chunk arithmetic as the in-process async path, but with
    // DataGenerate counted inside the data actor processes and ChunkCompute
    // inside the gradient actors — their totals ride the wire on
    // `DataDone` / `FinalizeResult` frames and merge into the barrier hub,
    // so a lost or double merge shows up as an exact count mismatch.  The
    // queue gauges also cross the boundary: Batch rises in the data reader
    // threads, Task rises at step dispatch and falls as chunk results
    // arrive.  And with a live JSONL sink, the paper gauges match the sync
    // trace row for row.
    support::use_cli_actor_exe();
    support::watchdog(300, "mp telemetry", || {
        let rt = Runtime::builtin();
        let cfg = tiny_cfg(Algorithm::DpAdaFest);

        let sync_path = sink_path("mp_sync");
        let mut scfg = cfg.clone();
        scfg.metrics_out = sync_path.clone();
        let gen = SynthCriteo::new(gen_cfg(&rt, &scfg));
        let mut trainer = Trainer::new(scfg.clone(), &rt).unwrap();
        let batch = trainer.batch_size();
        trainer.run_pctr(&gen).unwrap();

        let mp_path = sink_path("mp_procs");
        let mut acfg = cfg.clone();
        acfg.metrics_out = mp_path.clone();
        acfg.engine.processes = 2;
        acfg.engine.data_workers = 2;
        let run = engine::run_pctr(&acfg, &rt, gen_cfg(&rt, &acfg)).unwrap();
        let tele = &run.telemetry;
        assert_eq!(tele.steps, cfg.steps);
        let chunks_per_step = batch.div_ceil(16) as u64;
        assert_eq!(
            tele.stage(Stage::ChunkCompute).unwrap().count,
            cfg.steps * chunks_per_step,
            "grad actors' chunk spans must merge across the socket"
        );
        assert_eq!(
            tele.stage(Stage::DataGenerate).unwrap().count,
            cfg.steps,
            "data actors' generate spans must merge across the socket"
        );
        assert_eq!(tele.stage(Stage::Select).unwrap().count, cfg.steps);
        assert_eq!(tele.stage(Stage::Snapshot).unwrap().count, cfg.steps);
        assert_eq!(tele.stage(Stage::Collect).unwrap().count, cfg.steps);
        assert!(tele.batch_queue_max >= 1, "batch gauge never rose at the socket boundary");
        assert!(tele.task_queue_max >= 1, "task gauge never rose at dispatch");

        assert_paper_rows_identical(
            &read_jsonl(&sync_path),
            &read_jsonl(&mp_path),
            "mp paper gauges",
        );
    });
}

#[test]
fn merge_stage_totals_adds_nanos_and_counts() {
    // The wire-merge primitive the actor readers use: totals add into the
    // hub per stage — nanos to nanos, counts to counts — and stages absent
    // from the shipped list stay untouched.
    let hub = Telemetry::new();
    hub.time(Stage::Select, || std::hint::black_box(0));
    let (nanos0, count0) = hub.stage_total(Stage::Select);
    assert_eq!(count0, 1);

    hub.merge_stage_totals(&[
        (Stage::Select, 1_000, 3),
        (Stage::ChunkCompute, 2_500, 7),
    ]);
    hub.merge_stage_totals(&[(Stage::ChunkCompute, 500, 1)]);

    assert_eq!(hub.stage_total(Stage::Select), (nanos0 + 1_000, count0 + 3));
    assert_eq!(hub.stage_total(Stage::ChunkCompute), (3_000, 8));
    assert_eq!(hub.stage_total(Stage::DataGenerate), (0, 0), "untouched stage must stay zero");
}

#[test]
fn checked_in_bench_snapshot_parses_under_current_schema() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_engine.json");
    let text = std::fs::read_to_string(path).unwrap();
    let snap = BenchSnapshot::parse(&text).unwrap();
    assert_eq!(snap.schema_version, BENCH_SCHEMA_VERSION);
    assert_eq!(snap.bench, "engine_throughput");
    for row in &snap.rows {
        assert!(row.path == "sync" || row.path == "async", "{}", row.path);
        assert!(row.secs > 0.0 && row.steps_per_sec > 0.0);
        // only the async staleness-sweep rows may carry a non-zero window
        assert!(row.staleness == 0 || row.path == "async");
    }
}
