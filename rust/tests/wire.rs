//! Property suite for the multi-process wire format (`engine::wire`).
//!
//! The format's contract (see the module doc on `rust/src/engine/wire.rs`)
//! is three-fold, and each clause gets a property here:
//!
//! 1. **Canonical round-trip** — for every frame type, over random payloads
//!    (including NaN/inf/-0.0/subnormal floats built from raw bit patterns),
//!    `encode → decode → encode` reproduces the original bytes exactly.
//!    Comparing *re-encoded bytes* rather than decoded values is what makes
//!    the float check a `to_bits` equality: a NaN that survived decoding
//!    only counts if its exact payload bits survived too.
//! 2. **Strict and total decoding** — truncated frames, trailing garbage,
//!    flipped bytes, and arbitrary byte soup return errors (or, rarely, a
//!    valid frame that still re-encodes canonically); nothing panics and no
//!    length prefix can trigger an oversized allocation.
//! 3. **Framing layer** — `write_frame`/`read_frame` round-trip streams of
//!    frames, reject bodies above `MAX_FRAME` before allocating, and report
//!    short reads as errors.

use std::io::Cursor;

use sparse_dp_emb::coordinator::streaming::PriorPass;
use sparse_dp_emb::data::{Batch, CriteoConfig, GenConfig, PctrBatch, TextBatch, TextConfig};
use sparse_dp_emb::engine::wire::{read_frame, write_frame, Dec, Enc, Frame, GradInit, StepData, MAX_FRAME};
use sparse_dp_emb::engine::{BatchMsg, DataPlan};
use sparse_dp_emb::proptest::{check, ensure, usize_in, CaseResult};
use sparse_dp_emb::runtime::reference::ChunkGrads;
use sparse_dp_emb::sparse::OptimizerKind;
use sparse_dp_emb::telemetry::Stage;
use sparse_dp_emb::util::rng::Xoshiro256;

// ---------------------------------------------------------------------------
// random payload generators
// ---------------------------------------------------------------------------

/// An `f32` from a uniformly random bit pattern: hits NaNs (with payloads),
/// ±inf, -0.0, and subnormals far more often than any value-space generator.
fn any_f32(rng: &mut Xoshiro256) -> f32 {
    f32::from_bits(rng.next_u64() as u32)
}

fn any_f64(rng: &mut Xoshiro256) -> f64 {
    f64::from_bits(rng.next_u64())
}

fn f32_vec(rng: &mut Xoshiro256, max: usize) -> Vec<f32> {
    (0..usize_in(rng, 0, max)).map(|_| any_f32(rng)).collect()
}

fn u32_vec(rng: &mut Xoshiro256, max: usize) -> Vec<u32> {
    (0..usize_in(rng, 0, max))
        .map(|_| rng.next_u64() as u32)
        .collect()
}

fn i32_vec(rng: &mut Xoshiro256, max: usize) -> Vec<i32> {
    (0..usize_in(rng, 0, max))
        .map(|_| rng.next_u64() as i32)
        .collect()
}

fn usize_vec(rng: &mut Xoshiro256, max: usize) -> Vec<usize> {
    (0..usize_in(rng, 0, max))
        .map(|_| rng.next_u64() as usize)
        .collect()
}

/// A short string with multi-byte code points mixed in.
fn any_str(rng: &mut Xoshiro256) -> String {
    const ALPHABET: &[char] = &['a', 'Z', '0', '_', '/', '.', 'é', 'λ', '日', '🦀'];
    (0..usize_in(rng, 0, 10))
        .map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize])
        .collect()
}

fn any_prior(rng: &mut Xoshiro256) -> PriorPass {
    match rng.below(4) {
        0 => PriorPass::None,
        1 => PriorPass::FirstDay,
        2 => PriorPass::AllDays,
        _ => PriorPass::Sniff,
    }
}

fn any_gen(rng: &mut Xoshiro256) -> GenConfig {
    if rng.below(2) == 0 {
        GenConfig::Pctr(CriteoConfig {
            vocabs: usize_vec(rng, 6),
            num_numeric: rng.next_u64() as usize,
            seed: rng.next_u64(),
            drift: rng.below(2) == 1,
            drift_swap_frac: any_f64(rng),
            drift_teacher: any_f64(rng),
        })
    } else {
        GenConfig::Text(TextConfig {
            vocab: rng.next_u64() as usize,
            seq_len: rng.next_u64() as usize,
            num_classes: rng.next_u64() as usize,
            seed: rng.next_u64(),
            informative: rng.next_u64() as usize,
        })
    }
}

fn any_plan(rng: &mut Xoshiro256) -> DataPlan {
    DataPlan {
        seed: rng.next_u64(),
        batch_size: rng.next_u64() as usize,
        steps: rng.next_u64(),
        steps_per_day: if rng.below(2) == 1 { Some(rng.next_u64()) } else { None },
        with_counts: rng.below(2) == 1,
        prior: any_prior(rng),
    }
}

/// The codec carries structure, not semantics: shape fields and payload
/// lengths are deliberately *not* required to be mutually consistent here.
fn any_batch(rng: &mut Xoshiro256) -> Batch {
    if rng.below(2) == 0 {
        Batch::Pctr(PctrBatch {
            batch_size: rng.next_u64() as usize,
            num_features: rng.next_u64() as usize,
            num_numeric: rng.next_u64() as usize,
            cat: i32_vec(rng, 12),
            num: f32_vec(rng, 12),
            y: f32_vec(rng, 12),
        })
    } else {
        Batch::Text(TextBatch {
            batch_size: rng.next_u64() as usize,
            seq_len: rng.next_u64() as usize,
            ids: i32_vec(rng, 12),
            labels: i32_vec(rng, 12),
        })
    }
}

fn any_counts(rng: &mut Xoshiro256) -> Option<Vec<Vec<(u32, u32)>>> {
    if rng.below(2) == 0 {
        return None;
    }
    Some(
        (0..usize_in(rng, 0, 4))
            .map(|_| {
                (0..usize_in(rng, 0, 5))
                    .map(|_| (rng.next_u64() as u32, rng.next_u64() as u32))
                    .collect()
            })
            .collect(),
    )
}

fn any_stages(rng: &mut Xoshiro256) -> Vec<(Stage, u64, u64)> {
    (0..usize_in(rng, 0, Stage::COUNT))
        .map(|_| {
            let stage = Stage::ALL[usize_in(rng, 0, Stage::COUNT - 1)];
            (stage, rng.next_u64(), rng.next_u64())
        })
        .collect()
}

fn any_grads(rng: &mut Xoshiro256) -> ChunkGrads {
    ChunkGrads {
        lo: rng.next_u64() as usize,
        hi: rng.next_u64() as usize,
        loss_sum: any_f32(rng),
        dense_grads: (0..usize_in(rng, 0, 4)).map(|_| f32_vec(rng, 8)).collect(),
        zgrads: f32_vec(rng, 8),
        counts: (0..usize_in(rng, 0, 8))
            .map(|_| (rng.next_u64() as u32, any_f32(rng)))
            .collect(),
        scales: f32_vec(rng, 8),
    }
}

/// One random instance of every frame type — each property case exercises
/// all 13 variants, so coverage never depends on which tag a die roll picks.
fn all_frames(rng: &mut Xoshiro256) -> Vec<Frame> {
    vec![
        Frame::Hello { role: rng.next_u64() as u8, index: rng.next_u64() as u32 },
        Frame::DataInit {
            gen: any_gen(rng),
            plan: any_plan(rng),
            stride: rng.next_u64() as u32,
            offset: rng.next_u64() as u32,
        },
        Frame::GradInit(GradInit {
            model: any_str(rng),
            artifacts_dir: any_str(rng),
            seed: rng.next_u64(),
            opt_kind: if rng.below(2) == 0 { OptimizerKind::Sgd } else { OptimizerKind::Adagrad },
            lr: any_f32(rng),
            emb_params: u32_vec(rng, 6),
            n_owners: rng.next_u64() as u32,
            owner_index: rng.next_u64() as u32,
            shards: rng.next_u64() as u32,
            kernel_threads: rng.next_u64() as u32,
            kernel_backend: if rng.below(2) == 0 {
                sparse_dp_emb::kernels::KernelBackend::Scalar
            } else {
                sparse_dp_emb::kernels::KernelBackend::Simd
            },
            store_budget_mb: rng.next_u64(),
            store_dir: any_str(rng),
        }),
        Frame::Batch(BatchMsg {
            step: rng.next_u64(),
            batch: any_batch(rng),
            counts: any_counts(rng),
        }),
        Frame::DataDone { stages: any_stages(rng) },
        Frame::FetchRows {
            rows: (0..usize_in(rng, 0, 4)).map(|_| u32_vec(rng, 8)).collect(),
        },
        Frame::RowValues {
            values: (0..usize_in(rng, 0, 4)).map(|_| f32_vec(rng, 8)).collect(),
        },
        Frame::StepData(StepData {
            step: rng.next_u64(),
            chunk_lo: rng.next_u64() as u32,
            chunk_hi: rng.next_u64() as u32,
            c1: any_f32(rng),
            c2: any_f32(rng),
            batch: any_batch(rng),
            feats: (0..usize_in(rng, 0, 3))
                .map(|_| (u32_vec(rng, 6), f32_vec(rng, 12), rng.next_u64() as usize))
                .collect(),
            dense: (0..usize_in(rng, 0, 3))
                .map(|_| (rng.next_u64() as u32, f32_vec(rng, 8)))
                .collect(),
        }),
        Frame::ChunkResult {
            step: rng.next_u64(),
            chunk: rng.next_u64() as u32,
            grads: any_grads(rng),
        },
        Frame::Scatter {
            param: rng.next_u64() as u32,
            rows: u32_vec(rng, 8),
            values: f32_vec(rng, 16),
        },
        Frame::DenseScatter { param: rng.next_u64() as u32, values: f32_vec(rng, 16) },
        Frame::Finalize,
        Frame::FinalizeResult {
            tables: (0..usize_in(rng, 0, 3))
                .map(|_| (rng.next_u64() as u32, f32_vec(rng, 8), f32_vec(rng, 8)))
                .collect(),
            stages: any_stages(rng),
        },
    ]
}

fn roundtrip_canonical(frame: &Frame) -> CaseResult {
    let body = frame.encode();
    let decoded =
        Frame::decode(&body).map_err(|e| format!("decode failed on {frame:?}: {e}"))?;
    let re = decoded.encode();
    ensure(
        re == body,
        format!("re-encode of {decoded:?} differs from original encoding of {frame:?}"),
    )
}

// ---------------------------------------------------------------------------
// 1. canonical round-trip
// ---------------------------------------------------------------------------

#[test]
fn every_frame_type_roundtrips_bit_exactly() {
    check("frame round-trip is canonical", 150, |rng| {
        for frame in all_frames(rng) {
            roundtrip_canonical(&frame)?;
        }
        Ok(())
    });
}

#[test]
fn float_special_values_survive_as_exact_bit_patterns() {
    // The values a value-space comparison would mangle: NaN (quiet and
    // payload-carrying), infinities, signed zero, a subnormal.
    let specials = [
        f32::NAN,
        f32::from_bits(0x7fc0_dead), // NaN with a payload
        f32::from_bits(0xffc0_0001), // negative NaN
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0_f32,
        f32::from_bits(1), // smallest subnormal
        f32::MIN_POSITIVE,
    ];
    let frame = Frame::DenseScatter { param: 7, values: specials.to_vec() };
    let body = frame.encode();
    let decoded = Frame::decode(&body).unwrap();
    assert_eq!(decoded.encode(), body, "special float bits changed in flight");
    match decoded {
        Frame::DenseScatter { values, .. } => {
            let sent: Vec<u32> = specials.iter().map(|v| v.to_bits()).collect();
            let got: Vec<u32> = values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, sent, "to_bits mismatch on special values");
        }
        other => panic!("decoded to a different variant: {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// 2. strict, total decoding
// ---------------------------------------------------------------------------

#[test]
fn truncated_frames_error_rather_than_panic() {
    check("strict prefixes never decode", 60, |rng| {
        for frame in all_frames(rng) {
            let body = frame.encode();
            // Exhaustive prefixes for small bodies; a boundary-heavy sample
            // for big ones (all-prefixes on a StepData body is quadratic).
            let cuts: Vec<usize> = if body.len() <= 64 {
                (0..body.len()).collect()
            } else {
                let mut c = vec![0, 1, body.len() / 2, body.len() - 1];
                c.extend((0..12).map(|_| rng.below(body.len() as u64) as usize));
                c
            };
            for cut in cuts {
                ensure(
                    Frame::decode(&body[..cut]).is_err(),
                    format!("strict prefix of {} bytes decoded (cut at {cut})", body.len()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn trailing_bytes_after_a_valid_payload_error() {
    check("trailing bytes are rejected", 60, |rng| {
        for frame in all_frames(rng) {
            let mut body = frame.encode();
            for _ in 0..usize_in(rng, 1, 4) {
                body.push(rng.next_u64() as u8);
            }
            ensure(
                Frame::decode(&body).is_err(),
                "frame with trailing garbage decoded",
            )?;
        }
        Ok(())
    });
}

#[test]
fn flipped_bytes_stay_canonical_or_error() {
    check("single-byte corruption is strict", 120, |rng| {
        for frame in all_frames(rng) {
            let mut body = frame.encode();
            let pos = rng.below(body.len() as u64) as usize;
            let flip = (rng.below(255) + 1) as u8; // never a zero XOR
            body[pos] ^= flip;
            if let Ok(decoded) = Frame::decode(&body) {
                // A corrupted buffer may still parse (e.g. the flip landed in
                // a float payload) — but then it must re-encode to exactly
                // the corrupted bytes, or the codec is not canonical.
                ensure(
                    decoded.encode() == body,
                    format!("corrupted body decoded non-canonically at byte {pos}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn random_byte_soup_never_panics_and_stays_canonical() {
    check("garbage decode is total", 400, |rng| {
        let n = usize_in(rng, 0, 160);
        let body: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        if let Ok(decoded) = Frame::decode(&body) {
            ensure(
                decoded.encode() == body,
                "garbage decoded to a frame that re-encodes differently",
            )?;
        }
        Ok(())
    });
}

#[test]
fn unknown_tags_are_rejected() {
    assert!(Frame::decode(&[]).is_err(), "empty body decoded");
    assert!(Frame::decode(&[0]).is_err(), "tag 0 is not assigned");
    for tag in 14..=255u8 {
        assert!(Frame::decode(&[tag]).is_err(), "unassigned frame tag {tag} decoded");
    }
    // Out-of-range telemetry stage index inside an otherwise valid DataDone.
    let mut e = Enc::new();
    e.u8(5); // DataDone tag
    e.usize(1);
    e.u8(Stage::COUNT as u8);
    e.u64(0);
    e.u64(0);
    assert!(
        Frame::decode(&e.into_bytes()).is_err(),
        "out-of-range stage index decoded"
    );
}

#[test]
fn length_prefixes_cannot_force_oversized_allocations() {
    // A u64::MAX element count with no bytes behind it must be rejected by
    // the remaining-bytes guard, not handed to Vec::with_capacity.
    let mut e = Enc::new();
    e.u64(u64::MAX);
    let bytes = e.into_bytes();
    assert!(Dec::new(&bytes).u32s().is_err());
    assert!(Dec::new(&bytes).f32s().is_err());
    assert!(Dec::new(&bytes).usizes().is_err());
    assert!(Dec::new(&bytes).str().is_err());

    // Same guard, reached through a full frame decode: a FetchRows claiming
    // a huge outer vector.
    let mut e = Enc::new();
    e.u8(6); // FetchRows tag
    e.u64(1 << 40);
    assert!(Frame::decode(&e.into_bytes()).is_err());
}

#[test]
fn primitive_decoders_are_strict() {
    // bool accepts only 0 and 1 — anything else would break canonicality.
    for b in 2..=255u8 {
        assert!(Dec::new(&[b]).bool().is_err(), "bool byte {b} accepted");
    }
    assert!(!Dec::new(&[0]).bool().unwrap());
    assert!(Dec::new(&[1]).bool().unwrap());

    // Strings must be valid UTF-8.
    let mut e = Enc::new();
    e.usize(2);
    e.u8(0xff);
    e.u8(0xfe);
    assert!(Dec::new(&e.into_bytes()).str().is_err(), "invalid UTF-8 accepted");

    // finish() rejects unconsumed bytes.
    let mut e = Enc::new();
    e.u32(42);
    e.u8(9);
    let bytes = e.into_bytes();
    let mut d = Dec::new(&bytes);
    assert_eq!(d.u32().unwrap(), 42);
    assert!(d.finish().is_err(), "trailing byte survived finish()");
}

// ---------------------------------------------------------------------------
// 3. the framing layer
// ---------------------------------------------------------------------------

#[test]
fn frame_streams_roundtrip_through_write_and_read() {
    check("write_frame/read_frame stream round-trip", 40, |rng| {
        let frames = all_frames(rng);
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).map_err(|e| format!("write failed: {e}"))?;
        }
        let mut r = Cursor::new(buf);
        for f in &frames {
            let got = read_frame(&mut r).map_err(|e| format!("read failed: {e}"))?;
            ensure(got.encode() == f.encode(), "frame changed across the stream")?;
        }
        // The stream must be exactly drained.
        ensure(read_frame(&mut r).is_err(), "phantom frame after the stream end")
    });
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
    let err = read_frame(&mut Cursor::new(bytes)).unwrap_err();
    assert!(
        err.to_string().contains("MAX_FRAME"),
        "unexpected error for oversized prefix: {err}"
    );
}

#[test]
fn truncated_streams_error_mid_frame() {
    check("short reads error", 40, |rng| {
        let frame = &all_frames(rng)[usize_in(rng, 0, 12)];
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        let cut = rng.below(buf.len() as u64) as usize;
        buf.truncate(cut);
        ensure(
            read_frame(&mut Cursor::new(buf)).is_err(),
            format!("truncated stream (cut {cut}) produced a frame"),
        )
    });
}
