//! Minimal offline reimplementation of the `anyhow` error-handling API.
//!
//! The workspace builds with no network access, so the real crates.io
//! `anyhow` cannot be fetched; this path dependency provides the exact
//! subset the coordinator uses:
//!
//! * [`Error`] — an opaque, context-carrying error value;
//! * [`Result<T>`] — `std::result::Result<T, Error>`;
//! * [`Context`] — `.context(...)` / `.with_context(|| ...)` on `Result`
//!   and `Option`;
//! * [`bail!`], [`anyhow!`], [`ensure!`] macros;
//! * `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Swapping back to the real `anyhow` is a one-line Cargo.toml change — the
//! API here is call-compatible with how the crate is used.

use std::fmt;

/// An error message chain.  Context frames are stored outermost-first, so
/// `Display` prints `outer: inner: root`, matching `anyhow`'s `{:#}` style
/// (which is the useful rendering for a CLI tool).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (without inner frames).
    pub fn to_string_outer(&self) -> String {
        self.chain.first().cloned().unwrap_or_default()
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // main() -> Result<(), Error> prints via Debug; make it readable.
        write!(f, "{}", self.chain.join("\n  caused by: "))
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as the
// real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn bail_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn std_error_converts() {
        fn parse() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(parse().unwrap(), 12);
        let bad: Result<i32> = "nope".parse::<i32>().context("parsing");
        assert!(bad.unwrap_err().to_string().starts_with("parsing: "));
    }

    #[test]
    fn ensure_macro() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
    }
}
