//! Offline API stub for the `xla` crate (xla-rs PJRT bindings).
//!
//! The real crate links the native XLA/PJRT runtime, which is not in the
//! offline build environment.  This stub reproduces the exact API subset
//! `src/runtime/pjrt.rs` and `src/runtime/tensor.rs` consume so the
//! `--features xla` configuration always *compiles* (CI type-checks it):
//!
//! * [`Literal`] / [`ArrayShape`] / [`ElementType`] are fully functional
//!   host-side containers — the `HostTensor` ↔ literal round-trip tests
//!   pass under the stub;
//! * every PJRT entry point ([`PjRtClient::cpu`], compilation, execution,
//!   HLO parsing) returns a descriptive [`XlaError`] at runtime.
//!
//! Swap the workspace's `xla = { path = "vendor/xla" }` dependency for a
//! real xla-rs checkout to execute AOT artifacts.

use std::fmt;

/// Error type of every fallible stub call.
#[derive(Clone, Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: the vendored `xla` stub only type-checks the PJRT path; \
         point the Cargo.toml `xla` path dependency at a real xla-rs \
         checkout to execute artifacts"
    ))
}

/// Element dtypes (the subset plus enough neighbours for exhaustive-match
/// callers to stay honest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
}

impl fmt::Display for ElementType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Host buffer payload of a [`Literal`].
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LiteralData {
    fn len(&self) -> usize {
        match self {
            LiteralData::F32(d) => d.len(),
            LiteralData::I32(d) => d.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
        }
    }
}

/// Element types the stub can move in and out of literals.
pub trait NativeType: Copy {
    fn store(data: Vec<Self>) -> LiteralData;
    fn read(data: &LiteralData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn store(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }

    fn read(data: &LiteralData) -> Option<&[f32]> {
        match data {
            LiteralData::F32(d) => Some(d),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn store(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }

    fn read(data: &LiteralData) -> Option<&[i32]> {
        match data {
            LiteralData::I32(d) => Some(d),
            _ => None,
        }
    }
}

/// Host-side tensor literal — fully functional in the stub.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::store(data.to_vec()) }
    }

    /// Same buffer under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.data.ty() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| XlaError(format!("to_vec: literal is {:?}", self.data.ty())))
    }

    /// Tuple decomposition — only execution results are tuples, and the
    /// stub cannot execute.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Shape metadata of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module (stub: construction always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT device buffer handle (stub: never materialises).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled PJRT executable (stub: never materialises).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: construction always fails, with a pointer at the
/// real-crate swap).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn pjrt_entry_points_error_descriptively() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
